//! Evented TCP front-end: a single-threaded nonblocking reactor serving
//! every connection off one readiness loop (DESIGN.md §10).
//!
//! The threaded [`NetServer`](super::server::NetServer) spawns ~2 OS
//! threads per connection, which caps realistic fan-in far below the
//! "millions of users" north star. [`EventedServer`] serves the same
//! protocol with **O(1) threads**: nonblocking sockets driven by the
//! std-only [`reactor::Poller`], slab-allocated per-connection state,
//! incremental frame decoding ([`FrameDecoder`] — no per-frame body
//! allocation), buffered batched response writes, and a
//! poller-based [`reactor::Waker`] instead of the threaded core's
//! loopback self-connect shutdown hack.
//!
//! The readiness→decode→submit→settle path:
//!
//! 1. **readiness** — the poller reports a connection readable; the
//!    reactor drains the socket into the connection's scratch buffer;
//! 2. **decode** — complete frames are parsed in place into
//!    [`Msg`]s; partial frames wait for more bytes;
//! 3. **submit** — `InferRequest`s enter the coordinator via
//!    [`Server::submit_to_opts`] with a per-connection
//!    [`CompletionNotify`] hook; the returned [`Pending`] joins the
//!    connection's FIFO reply queue (which is what preserves
//!    answer-in-request-order under pipelining);
//! 4. **settle** — when a worker answers, the hook pushes the
//!    connection's token onto the completion queue and wakes the
//!    poller; the reactor `try_wait`s the queue front(s), encodes the
//!    responses into the connection's write buffer, and flushes.
//!
//! **Oracle contract**: the threaded core is the differential oracle —
//! exactly how the interpreter anchors the compiled engine. Both cores
//! share [`NetMetrics`], the [`ErrorCode`] mapping, the
//! [`NetServerConfig`] tunables and drain semantics, and
//! `tests/net_evented.rs` pins byte-identical responses plus exact
//! counter reconciliation between them on seeded replays.

use std::collections::VecDeque;
use std::io::{self, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    CompletionNotify, NetMetrics, NetMetricsSnapshot, Pending, ReactorStats,
    ReactorStatsSnapshot, Server, SubmitOpts,
};

use super::proto::{ErrorCode, FrameDecoder, Msg};
use super::reactor::{self, Event, Interest, Poller, WakeReader, Waker};
use super::server::NetServerConfig;

const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKER: usize = 1;
/// Connection tokens are slab index + this offset.
const TOKEN_CONN0: usize = 2;

/// Socket-read chunks consumed per readiness event per connection — a
/// fairness bound so one firehose peer cannot starve the loop (the
/// level-triggered poller re-reports leftover bytes immediately).
const MAX_READS_PER_EVENT: usize = 8;
/// A connection's write buffer is released back to the allocator once
/// drained past this size, so a burst does not pin memory forever.
const RETAIN_OUT: usize = 256 * 1024;

/// State shared between the reactor thread, the shutdown path, and the
/// per-request completion hooks running on coordinator workers.
struct Shared {
    waker: Waker,
    /// Serving; `false` starts the drain.
    open: AtomicBool,
    /// The coordinator drain has finished: every accepted request is
    /// answerable, the reactor may do its final settle-and-flush sweep.
    drained: AtomicBool,
    /// Slab indices with newly-settled replies, pushed by
    /// [`ConnNotify::notify`], drained by the reactor each pass.
    completions: Mutex<Vec<usize>>,
}

/// One connection's completion hook: dedups via `queued` so a burst of
/// settles costs one token + one wake, not one syscall per response.
struct ConnNotify {
    slab_idx: usize,
    queued: AtomicBool,
    shared: Arc<Shared>,
}

impl CompletionNotify for ConnNotify {
    fn notify(&self) {
        if !self.queued.swap(true, Ordering::AcqRel) {
            self.shared
                .completions
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(self.slab_idx);
            self.shared.waker.wake();
        }
    }
}

/// A queued reply, FIFO per connection — the evented image of the
/// threaded core's `WriteItem`: answers leave in request order.
enum Reply {
    Ready(Msg),
    Wait(u64, Pending),
}

/// Slab-allocated per-connection state. The slab index is stable for
/// the connection's lifetime (freed slots are reused), so both the
/// poller token and the completion hook key on it.
struct Conn {
    stream: TcpStream,
    decoder: FrameDecoder,
    replies: VecDeque<Reply>,
    /// Encoded-but-unwritten response bytes (`out_pos..` is pending).
    out: Vec<u8>,
    out_pos: usize,
    notify: Arc<ConnNotify>,
    registered: bool,
    interest: Interest,
    /// EOF seen, read error, or framing lost — no more requests.
    read_closed: bool,
    /// Protocol violation answered; stop parsing buffered bytes too.
    closing: bool,
    /// Write side dead: settle + count replies, write nothing.
    sink_only: bool,
    /// Read interest withheld because `replies` hit the configured
    /// depth (per-connection backpressure).
    paused: bool,
    /// Set when a write would block; cleared on any write progress.
    /// Drives the write-stall teardown timer.
    stalled_since: Option<Instant>,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.sink_only || self.out_pos >= self.out.len()
    }
}

/// The running evented front-end. API mirrors the threaded
/// [`NetServer`](super::server::NetServer); dropping it shuts down.
pub struct EventedServer {
    addr: SocketAddr,
    metrics: Arc<NetMetrics>,
    stats: Arc<ReactorStats>,
    coordinator: Arc<Server>,
    shared: Arc<Shared>,
    reactor: Option<std::thread::JoinHandle<()>>,
}

impl EventedServer {
    /// Bind `addr` and start the reactor thread serving `coordinator`.
    pub fn bind(addr: &str, coordinator: Arc<Server>) -> Result<EventedServer, String> {
        EventedServer::bind_with(addr, coordinator, NetServerConfig::default())
    }

    /// [`bind`](EventedServer::bind) with explicit tunables (shared with
    /// the threaded core — see [`NetServerConfig`]).
    pub fn bind_with(
        addr: &str,
        coordinator: Arc<Server>,
        config: NetServerConfig,
    ) -> Result<EventedServer, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking listener: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let mut poller = Poller::new().map_err(|e| format!("poller: {e}"))?;
        let (waker, wake_rx) = reactor::waker().map_err(|e| format!("waker: {e}"))?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
            .map_err(|e| format!("register listener: {e}"))?;
        poller
            .register(wake_rx.as_raw_fd(), TOKEN_WAKER, Interest::READ)
            .map_err(|e| format!("register waker: {e}"))?;
        let metrics = Arc::new(NetMetrics::default());
        let stats = Arc::new(ReactorStats::default());
        let shared = Arc::new(Shared {
            waker,
            open: AtomicBool::new(true),
            drained: AtomicBool::new(false),
            completions: Mutex::new(Vec::new()),
        });
        let specs: Arc<Vec<(String, u32)>> = Arc::new(
            coordinator
                .model_specs()
                .into_iter()
                .map(|(id, len)| (id, len as u32))
                .collect(),
        );
        let reactor = Reactor {
            poller,
            listener: Some(listener),
            wake_rx,
            conns: Vec::new(),
            free: Vec::new(),
            live: 0,
            stalled: 0,
            draining: false,
            final_sweep_done: false,
            coordinator: Arc::clone(&coordinator),
            specs,
            metrics: Arc::clone(&metrics),
            stats: Arc::clone(&stats),
            shared: Arc::clone(&shared),
            config,
        };
        let handle = std::thread::Builder::new()
            .name("cnn-flow-net-reactor".into())
            .spawn(move || reactor.run())
            .map_err(|e| format!("spawn reactor: {e}"))?;
        Ok(EventedServer {
            addr: local,
            metrics,
            stats,
            coordinator,
            shared,
            reactor: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time net-layer counters (same struct as the threaded
    /// core — the cross-core reconciliation contract).
    pub fn metrics(&self) -> NetMetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Readiness-loop counters (evented core only).
    pub fn reactor_stats(&self) -> ReactorStatsSnapshot {
        self.stats.snapshot()
    }

    /// Shared handle to the live net counters (see
    /// [`NetServer::metrics_handle`](super::server::NetServer::metrics_handle)).
    pub fn metrics_handle(&self) -> Arc<NetMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Shared handle to the live reactor counters.
    pub fn reactor_handle(&self) -> Arc<ReactorStats> {
        Arc::clone(&self.stats)
    }

    /// Graceful drain with the same ordering contract as the threaded
    /// core: stop accepting + EOF every read half (the reactor does both
    /// on the first wake), flush the coordinator so every accepted
    /// request is answered, then let the reactor settle and write those
    /// final responses before the sockets close. Idempotent; also runs
    /// on drop. The wake-up is the poller-based waker — no loopback
    /// self-connect involved.
    pub fn shutdown(&mut self) -> NetMetricsSnapshot {
        if self.shared.open.swap(false, Ordering::SeqCst) {
            self.shared.waker.wake();
            self.coordinator.drain_shared();
            self.shared.drained.store(true, Ordering::SeqCst);
            self.shared.waker.wake();
            if let Some(h) = self.reactor.take() {
                let _ = h.join();
            }
        }
        self.metrics.snapshot()
    }
}

impl Drop for EventedServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The reactor: everything the event-loop thread owns.
struct Reactor {
    poller: Poller,
    listener: Option<TcpListener>,
    wake_rx: WakeReader,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Live connections (slab occupancy).
    live: usize,
    /// Connections currently write-stalled (timer active).
    stalled: usize,
    draining: bool,
    final_sweep_done: bool,
    coordinator: Arc<Server>,
    specs: Arc<Vec<(String, u32)>>,
    metrics: Arc<NetMetrics>,
    stats: Arc<ReactorStats>,
    shared: Arc<Shared>,
    config: NetServerConfig,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if !self.shared.open.load(Ordering::Acquire) {
                self.begin_drain();
            }
            self.process_completions();
            self.sweep_stalls();
            if self.draining && self.shared.drained.load(Ordering::Acquire) {
                if !self.final_sweep_done {
                    self.final_sweep_done = true;
                    // The coordinator has answered everything: one sweep
                    // settles every queue (including workers that died
                    // without answering — `try_wait` maps those to the
                    // Draining error), so exit only waits on flushes.
                    for idx in 0..self.conns.len() {
                        if self.conns[idx].is_some() {
                            self.service(idx, false, false);
                        }
                    }
                }
                if self.live == 0 {
                    return;
                }
            }
            let timeout = self.next_timeout();
            if self.poller.wait(&mut events, timeout).is_err() {
                // A broken poller cannot be recovered; back off so a
                // persistent failure does not spin, then re-check flags.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            if !events.is_empty() {
                self.stats.polls.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .events
                    .fetch_add(events.len() as u64, Ordering::Relaxed);
            }
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => {
                        self.wake_rx.drain();
                        self.stats.wakeups.fetch_add(1, Ordering::Relaxed);
                    }
                    t => self.service(t - TOKEN_CONN0, ev.readable, false),
                }
            }
        }
    }

    // -- accept --------------------------------------------------------

    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => self.add_conn(stream),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(_) => {
                    // Transient accept failure (e.g. EMFILE under a
                    // connection flood): back off briefly — the same
                    // discipline as the threaded accept loop. Level-
                    // triggered readiness retries automatically.
                    std::thread::sleep(Duration::from_millis(10));
                    return;
                }
            }
        }
    }

    fn add_conn(&mut self, stream: TcpStream) {
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            // Cannot serve a blocking socket off the reactor: refuse
            // before counting, mirroring the threaded core's
            // try_clone-failure refusal.
            return;
        }
        let idx = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.conns.len() - 1
        });
        let notify = Arc::new(ConnNotify {
            slab_idx: idx,
            queued: AtomicBool::new(false),
            shared: Arc::clone(&self.shared),
        });
        let mut conn = Conn {
            stream,
            decoder: FrameDecoder::new(),
            replies: VecDeque::new(),
            out: Vec::new(),
            out_pos: 0,
            notify,
            registered: false,
            interest: Interest {
                readable: false,
                writable: false,
            },
            read_closed: false,
            closing: false,
            sink_only: false,
            paused: false,
            stalled_since: None,
        };
        self.metrics.connections.fetch_add(1, Ordering::Relaxed);
        self.live += 1;
        if self.draining {
            // Raced the drain: no requests will be read, tear down as
            // soon as `finish` sees the empty state.
            let _ = conn.stream.shutdown(Shutdown::Read);
            conn.read_closed = true;
        }
        self.finish(idx, conn);
    }

    // -- per-connection service pass ------------------------------------

    /// One full pass over a connection: optional socket fill, then the
    /// decode→submit→settle loop (re-entered when settling frees reply-
    /// queue space for already-buffered frames), then flush, then
    /// teardown-or-rearm.
    fn service(&mut self, idx: usize, fill: bool, via_completion: bool) {
        let Some(mut conn) = self.conns.get_mut(idx).and_then(Option::take) else {
            return;
        };
        if fill {
            self.fill(&mut conn);
        }
        loop {
            let backpressured = self.parse_frames(&mut conn);
            let settled = self.settle(&mut conn);
            if via_completion {
                self.stats.completions.fetch_add(settled, Ordering::Relaxed);
            }
            if !(backpressured && conn.replies.len() < self.config.writer_queue_depth) {
                break;
            }
        }
        self.flush(&mut conn);
        self.finish(idx, conn);
    }

    /// Drain the socket into the scratch buffer (bounded per pass).
    fn fill(&mut self, conn: &mut Conn) {
        if conn.read_closed {
            return;
        }
        for _ in 0..MAX_READS_PER_EVENT {
            match conn.decoder.read_from(&mut conn.stream) {
                Ok(0) => {
                    // Clean EOF at a frame boundary or truncation mid-
                    // frame: either way reads are over. Truncation (like
                    // transport errors) closes quietly — `err_malformed`
                    // stays a wire-violation counter, matching the
                    // threaded reader.
                    conn.read_closed = true;
                    return;
                }
                Ok(_) => {}
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.read_closed = true;
                    return;
                }
            }
        }
    }

    /// Decode buffered frames and dispatch them, stopping at the reply-
    /// queue depth (returns true — backpressure) or when no complete
    /// frame remains (returns false).
    fn parse_frames(&mut self, conn: &mut Conn) -> bool {
        loop {
            if conn.closing {
                return false;
            }
            if conn.replies.len() >= self.config.writer_queue_depth {
                return true;
            }
            match conn.decoder.next() {
                Ok(Some(msg)) => self.dispatch_msg(conn, msg),
                Ok(None) => return false,
                Err(e) => {
                    // Framing is lost: answer with a typed error, stop
                    // reading — identical to the threaded reader path.
                    self.metrics.err_malformed.fetch_add(1, Ordering::Relaxed);
                    conn.replies.push_back(Reply::Ready(Msg::InferErr {
                        id: 0,
                        code: ErrorCode::Malformed,
                        message: e.to_string(),
                    }));
                    conn.closing = true;
                    conn.read_closed = true;
                    let _ = conn.stream.shutdown(Shutdown::Read);
                    return false;
                }
            }
        }
    }

    /// The evented image of the threaded `dispatch`: same counters, same
    /// `ErrorCode` classification, same protocol-violation handling.
    fn dispatch_msg(&mut self, conn: &mut Conn, msg: Msg) {
        match msg {
            Msg::InferRequest {
                id,
                model,
                frame,
                deadline_us,
                class,
            } => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                if !self.shared.open.load(Ordering::Acquire) {
                    self.count_error(ErrorCode::Draining);
                    conn.replies.push_back(Reply::Ready(Msg::InferErr {
                        id,
                        code: ErrorCode::Draining,
                        message: "drain in progress".into(),
                    }));
                    return;
                }
                let notify: Arc<dyn CompletionNotify> = conn.notify.clone();
                let opts = SubmitOpts { deadline_us, class };
                match self
                    .coordinator
                    .submit_to_opts(&model, frame, opts, Some(notify))
                {
                    Ok(pending) => conn.replies.push_back(Reply::Wait(id, pending)),
                    Err(e) => {
                        let code = ErrorCode::from_reject(&e);
                        self.count_error(code);
                        conn.replies.push_back(Reply::Ready(Msg::InferErr {
                            id,
                            code,
                            message: e,
                        }));
                    }
                }
            }
            Msg::ListModels => conn.replies.push_back(Reply::Ready(Msg::ModelList {
                models: self.specs.as_ref().clone(),
            })),
            Msg::MetricsText => {
                let text = self
                    .coordinator
                    .metrics_text(Some(&self.metrics.snapshot()), Some(&self.stats.snapshot()));
                conn.replies.push_back(Reply::Ready(Msg::MetricsTextReply { text }));
            }
            Msg::InferOk { .. } | Msg::InferErr { .. } | Msg::ModelList { .. }
            | Msg::MetricsTextReply { .. } => {
                self.count_error(ErrorCode::Malformed);
                conn.replies.push_back(Reply::Ready(Msg::InferErr {
                    id: 0,
                    code: ErrorCode::Malformed,
                    message: "unexpected message kind from client".into(),
                }));
                conn.closing = true;
                conn.read_closed = true;
                let _ = conn.stream.shutdown(Shutdown::Read);
            }
        }
    }

    fn count_error(&self, code: ErrorCode) {
        let counter = match code {
            ErrorCode::QueueFull => &self.metrics.err_queue_full,
            ErrorCode::SloMiss => &self.metrics.err_slo_miss,
            ErrorCode::InvalidFrame => &self.metrics.err_invalid_frame,
            ErrorCode::UnknownModel => &self.metrics.err_unknown_model,
            ErrorCode::Draining => &self.metrics.err_draining,
            ErrorCode::Malformed => &self.metrics.err_malformed,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Settle the reply queue front-to-back (FIFO — request order) as
    /// far as answers have arrived, encoding into the write buffer.
    /// Returns the number of coordinator replies settled. Counters move
    /// even in sink-only mode, exactly like the threaded writer: every
    /// decoded request lands in exactly one counter.
    fn settle(&mut self, conn: &mut Conn) -> u64 {
        let mut settled = 0u64;
        loop {
            let msg = match conn.replies.front_mut() {
                None => break,
                Some(Reply::Ready(_)) => match conn.replies.pop_front() {
                    Some(Reply::Ready(m)) => m,
                    _ => unreachable!("front was Ready"),
                },
                Some(Reply::Wait(id, pending)) => {
                    let id = *id;
                    match pending.try_wait() {
                        None => break,
                        Some(Ok(resp)) => {
                            settled += 1;
                            self.metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
                            conn.replies.pop_front();
                            Msg::InferOk {
                                id,
                                argmax: resp.argmax as u32,
                                sim_latency_cycles: resp.sim_latency_cycles,
                                logits: resp.logits,
                                predicted_cycles: resp.predicted_cycles,
                                slo_met: resp.slo_met,
                            }
                        }
                        Some(Err(e)) => {
                            settled += 1;
                            let code = ErrorCode::from_reject(&e);
                            self.count_error(code);
                            conn.replies.pop_front();
                            Msg::InferErr {
                                id,
                                code,
                                message: e,
                            }
                        }
                    }
                }
            };
            if !conn.sink_only && msg.encode_into(&mut conn.out).is_err() {
                // Parity with the threaded writer: an unencodable
                // response poisons the connection's write side.
                self.mark_sink(conn);
            }
        }
        settled
    }

    /// Push buffered bytes at the socket until done or `WouldBlock`.
    fn flush(&mut self, conn: &mut Conn) {
        if conn.sink_only {
            conn.out.clear();
            conn.out_pos = 0;
            return;
        }
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => {
                    self.mark_sink(conn);
                    return;
                }
                Ok(n) => {
                    conn.out_pos += n;
                    if conn.stalled_since.take().is_some() {
                        self.stalled -= 1;
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if conn.stalled_since.is_none() {
                        conn.stalled_since = Some(Instant::now());
                        self.stalled += 1;
                    }
                    break;
                }
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.mark_sink(conn);
                    return;
                }
            }
        }
        if conn.out_pos >= conn.out.len() {
            conn.out.clear();
            conn.out_pos = 0;
            if conn.out.capacity() > RETAIN_OUT {
                conn.out = Vec::new();
            }
        } else if conn.out_pos >= 64 * 1024 {
            // Partial flush with a large dead prefix: slide instead of
            // letting the buffer grow unboundedly.
            let len = conn.out.len();
            conn.out.copy_within(conn.out_pos..len, 0);
            conn.out.truncate(len - conn.out_pos);
            conn.out_pos = 0;
        }
    }

    /// Write side is dead: drop buffered bytes, stop stall tracking —
    /// replies keep settling (and counting) until the queue drains.
    fn mark_sink(&mut self, conn: &mut Conn) {
        conn.sink_only = true;
        conn.out.clear();
        conn.out_pos = 0;
        if conn.stalled_since.take().is_some() {
            self.stalled -= 1;
        }
    }

    /// Teardown when finished (all replies settled, bytes flushed or
    /// abandoned, reads over) — else re-arm poller interest and return
    /// the connection to its slab slot.
    fn finish(&mut self, idx: usize, mut conn: Conn) {
        if conn.read_closed && conn.replies.is_empty() && conn.flushed() {
            if conn.registered {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
            }
            if conn.stalled_since.take().is_some() {
                self.stalled -= 1;
            }
            let _ = conn.stream.shutdown(Shutdown::Both);
            self.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
            self.live -= 1;
            self.free.push(idx);
            return;
        }
        self.update_interest(&mut conn, idx);
        self.conns[idx] = Some(conn);
    }

    /// Reconcile poller registration with what the connection can make
    /// progress on. A conn wanting nothing (e.g. settling replies for a
    /// dead peer) is deregistered entirely: with level-triggered
    /// polling, a hung-up socket would otherwise storm HUP events.
    fn update_interest(&mut self, conn: &mut Conn, idx: usize) {
        let depth_ok = conn.replies.len() < self.config.writer_queue_depth;
        if !depth_ok && !conn.read_closed && !conn.paused {
            conn.paused = true;
            self.stats.read_pauses.fetch_add(1, Ordering::Relaxed);
        }
        if depth_ok {
            conn.paused = false;
        }
        let want = Interest {
            readable: !conn.read_closed && depth_ok,
            writable: !conn.sink_only && conn.out_pos < conn.out.len(),
        };
        let fd = conn.stream.as_raw_fd();
        let token = TOKEN_CONN0 + idx;
        if !want.readable && !want.writable {
            if conn.registered && self.poller.deregister(fd).is_ok() {
                conn.registered = false;
            }
        } else if !conn.registered {
            if self.poller.register(fd, token, want).is_ok() {
                conn.registered = true;
                conn.interest = want;
            }
        } else if want != conn.interest && self.poller.modify(fd, token, want).is_ok() {
            conn.interest = want;
        }
    }

    // -- wakeup-driven work ---------------------------------------------

    /// Settle connections whose workers signalled completion.
    fn process_completions(&mut self) {
        let tokens = {
            let mut q = self
                .shared
                .completions
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *q)
        };
        for idx in tokens {
            // Reset the dedup flag *before* settling: a notify firing
            // after our sweep re-queues the token for the next pass.
            match self.conns.get(idx) {
                Some(Some(conn)) => conn.notify.queued.store(false, Ordering::Release),
                _ => continue, // conn torn down meanwhile: stale token
            }
            self.service(idx, false, true);
        }
    }

    /// First wake after `shutdown`: stop accepting, EOF every read half.
    fn begin_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        if let Some(l) = self.listener.take() {
            let _ = self.poller.deregister(l.as_raw_fd());
        }
        for idx in 0..self.conns.len() {
            if let Some(conn) = self.conns[idx].as_mut() {
                let _ = conn.stream.shutdown(Shutdown::Read);
                conn.read_closed = true;
                self.service(idx, false, false);
            }
        }
    }

    // -- write-stall timeouts -------------------------------------------

    fn next_timeout(&self) -> Option<Duration> {
        if self.stalled == 0 {
            return None;
        }
        let now = Instant::now();
        let mut min = self.config.write_stall_timeout;
        for conn in self.conns.iter().flatten() {
            if let Some(t) = conn.stalled_since {
                let left = (t + self.config.write_stall_timeout).saturating_duration_since(now);
                min = min.min(left);
            }
        }
        Some(min.max(Duration::from_millis(1)))
    }

    /// Tear down connections whose peer stopped reading for longer than
    /// the configured stall timeout (buffered replies are abandoned;
    /// unsettled ones still settle and count before the slot frees).
    fn sweep_stalls(&mut self) {
        if self.stalled == 0 {
            return;
        }
        let now = Instant::now();
        for idx in 0..self.conns.len() {
            let expired = matches!(
                &self.conns[idx],
                Some(c) if c.stalled_since
                    .is_some_and(|t| now.duration_since(t) >= self.config.write_stall_timeout)
            );
            if !expired {
                continue;
            }
            let mut conn = self.conns[idx].take().expect("checked above");
            self.stats.stall_teardowns.fetch_add(1, Ordering::Relaxed);
            self.mark_sink(&mut conn);
            conn.read_closed = true;
            let _ = conn.stream.shutdown(Shutdown::Both);
            self.settle(&mut conn);
            self.finish(idx, conn);
        }
    }
}
