//! Multiplexed fan-in load generator: N pipelined connections driven
//! off **one** client thread via the same std-only
//! [`reactor::Poller`] the evented server uses.
//!
//! The pooled blocking [`Client`](super::client::Client) measures
//! protocol semantics one socket at a time; this driver measures the
//! server's *fan-in* behaviour — thousands of concurrent pipelined
//! connections — which a thread-per-socket client cannot reach under
//! ordinary fd/thread limits. It powers the connections-vs-throughput
//! and RTT-under-fan-in rows of `BENCH_pipeline.json` and the 1k/10k
//! connection smoke tests.
//!
//! Request frames are generated deterministically from `seed`, so the
//! exact byte stream each connection sends is reproducible across
//! cores and runs. Responses arrive in request order per connection
//! (the protocol's pipelining contract), so round-trip times pair the
//! oldest in-flight send with each arriving reply.

use std::io::{self, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::os::unix::io::AsRawFd;
use std::time::{Duration, Instant};

use super::proto::{FrameDecoder, Msg};
use super::reactor::{Event, Interest, Poller};

/// Fan-in run shape.
#[derive(Debug, Clone, Copy)]
pub struct FanInConfig {
    /// Concurrent TCP connections.
    pub connections: usize,
    /// Requests sent per connection.
    pub requests_per_conn: usize,
    /// Max in-flight (unanswered) requests per connection. `1` is the
    /// closed-loop RTT probe; `requests_per_conn` is fully pipelined.
    pub window: usize,
    /// Deterministic frame-content seed.
    pub seed: u64,
    /// Abort the run (with an error) if it exceeds this wall time.
    pub deadline: Option<Duration>,
}

impl Default for FanInConfig {
    fn default() -> Self {
        FanInConfig {
            connections: 64,
            requests_per_conn: 16,
            window: 8,
            seed: 0xFA51,
            deadline: Some(Duration::from_secs(120)),
        }
    }
}

/// What a fan-in run observed.
#[derive(Debug, Clone, Copy)]
pub struct FanInReport {
    pub connections: usize,
    pub sent: u64,
    /// `InferOk` responses.
    pub ok: u64,
    /// Typed `InferErr` responses (e.g. backpressure under overload).
    pub errors: u64,
    /// Time to establish every connection.
    pub connect_wall: Duration,
    /// Time from first request byte to last settled response.
    pub wall: Duration,
    pub rtt_p50_us: f64,
    pub rtt_p99_us: f64,
    pub rtt_max_us: f64,
}

impl FanInReport {
    /// Settled responses per second of request-phase wall time.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        (self.ok + self.errors) as f64 / secs
    }
}

struct FConn {
    stream: TcpStream,
    decoder: FrameDecoder,
    out: Vec<u8>,
    out_pos: usize,
    sent: usize,
    recvd: usize,
    /// Send timestamps of in-flight requests, oldest first.
    inflight: std::collections::VecDeque<Instant>,
    interest: Interest,
    registered: bool,
    done: bool,
}

const NO_INTEREST: Interest = Interest {
    readable: false,
    writable: false,
};

/// Splitmix64 — a frame byte generator, not a statistical RNG.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The deterministic frame connection `conn` sends as request `req`.
pub fn frame_for(seed: u64, conn: usize, req: usize, frame_len: usize) -> Vec<i64> {
    (0..frame_len)
        .map(|k| {
            let z = mix(seed ^ ((conn as u64) << 40) ^ ((req as u64) << 20) ^ k as u64);
            (z % 256) as i64 - 128
        })
        .collect()
}

/// Drive `cfg.connections` pipelined connections against `addr`, all
/// requests targeting `model` with `frame_len`-element frames. Returns
/// the aggregate report, or an error on transport failure / protocol
/// violation / deadline.
pub fn run(
    addr: SocketAddr,
    model: &str,
    frame_len: usize,
    cfg: &FanInConfig,
) -> Result<FanInReport, String> {
    if cfg.connections == 0 || cfg.requests_per_conn == 0 || cfg.window == 0 {
        return Err("fan-in config must have nonzero connections/requests/window".into());
    }
    let started = Instant::now();
    let mut poller = Poller::new().map_err(|e| format!("fan-in poller: {e}"))?;
    let mut conns: Vec<FConn> = Vec::with_capacity(cfg.connections);
    for _ in 0..cfg.connections {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("fan-in connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_nonblocking(true)
            .map_err(|e| format!("fan-in nonblocking: {e}"))?;
        conns.push(FConn {
            stream,
            decoder: FrameDecoder::new(),
            out: Vec::new(),
            out_pos: 0,
            sent: 0,
            recvd: 0,
            inflight: std::collections::VecDeque::new(),
            interest: NO_INTEREST,
            registered: false,
            done: false,
        });
    }
    let connect_wall = started.elapsed();

    let mut ok = 0u64;
    let mut errors = 0u64;
    let mut rtts_ns: Vec<u64> = Vec::with_capacity(cfg.connections * cfg.requests_per_conn);
    let request_phase = Instant::now();
    let mut live = conns.len();

    // Prime every connection: fill the window, flush, arm interest.
    for idx in 0..conns.len() {
        pump(
            &mut conns[idx],
            idx,
            model,
            frame_len,
            cfg,
            &mut poller,
            &mut ok,
            &mut errors,
            &mut rtts_ns,
            &mut live,
        )?;
    }

    let mut events: Vec<Event> = Vec::new();
    while live > 0 {
        if let Some(deadline) = cfg.deadline {
            if started.elapsed() > deadline {
                return Err(format!(
                    "fan-in deadline exceeded: {live} connections unfinished after {deadline:?}"
                ));
            }
        }
        poller
            .wait(&mut events, Some(Duration::from_millis(200)))
            .map_err(|e| format!("fan-in poll: {e}"))?;
        for ev in &events {
            let idx = ev.token;
            if conns[idx].done {
                continue;
            }
            pump(
                &mut conns[idx],
                idx,
                model,
                frame_len,
                cfg,
                &mut poller,
                &mut ok,
                &mut errors,
                &mut rtts_ns,
                &mut live,
            )?;
        }
    }
    let wall = request_phase.elapsed();

    rtts_ns.sort_unstable();
    let pick = |q: f64| -> f64 {
        if rtts_ns.is_empty() {
            return 0.0;
        }
        let pos = ((rtts_ns.len() - 1) as f64 * q).round() as usize;
        rtts_ns[pos] as f64 / 1_000.0
    };
    Ok(FanInReport {
        connections: cfg.connections,
        sent: conns.iter().map(|c| c.sent as u64).sum(),
        ok,
        errors,
        connect_wall,
        wall,
        rtt_p50_us: pick(0.50),
        rtt_p99_us: pick(0.99),
        rtt_max_us: rtts_ns.last().map_or(0.0, |&n| n as f64 / 1_000.0),
    })
}

/// Run the connections-vs-throughput ladder: at each rung, a fresh
/// synthetic-model coordinator behind each network core takes the same
/// fan-in load — `requests_per_conn` pipelined requests per connection
/// for throughput, then a closed-loop (window = 1) probe for RTT.
/// Shared by `cnn-flow bench --fanin` and `benches/bench_pipeline.rs`
/// so `BENCH_pipeline.json` rows stay comparable wherever produced.
pub fn ladder(
    rungs: &[usize],
    requests_per_conn: usize,
) -> Result<Vec<crate::util::bench::FanInComparison>, String> {
    use crate::coordinator::{Server, ServerConfig};
    use crate::quant::QModel;

    let mut rows = Vec::new();
    for &connections in rungs {
        let mut rps = [0.0f64; 2];
        let mut rtt_p99 = [0.0f64; 2];
        let cores = [super::NetCore::Threaded, super::NetCore::Evented];
        for (i, core) in cores.into_iter().enumerate() {
            let config = ServerConfig {
                workers: 2,
                max_batch: 16,
                queue_depth: 4096,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            };
            let server = Server::start(QModel::synthetic(12, 8, 10, 0xBE7C), config, None)
                .map(std::sync::Arc::new)
                .map_err(|e| format!("{core}: {e}"))?;
            let (model, frame_len) = server
                .model_specs()
                .first()
                .cloned()
                .ok_or_else(|| "fan-in server advertises no models".to_string())?;
            let mut net = super::FrontEnd::bind(core, "127.0.0.1:0", std::sync::Arc::clone(&server))
                .map_err(|e| format!("{core}: {e}"))?;
            let addr = net.local_addr();
            let throughput = run(
                addr,
                &model,
                frame_len,
                &FanInConfig {
                    connections,
                    requests_per_conn,
                    window: 8,
                    seed: 0xFA51,
                    deadline: Some(Duration::from_secs(300)),
                },
            )
            .map_err(|e| format!("{core} x{connections} pipelined: {e}"))?;
            let rtt = run(
                addr,
                &model,
                frame_len,
                &FanInConfig {
                    connections,
                    requests_per_conn: 4,
                    window: 1,
                    seed: 0xFA52,
                    deadline: Some(Duration::from_secs(300)),
                },
            )
            .map_err(|e| format!("{core} x{connections} closed-loop: {e}"))?;
            net.shutdown();
            rps[i] = throughput.throughput_rps();
            rtt_p99[i] = rtt.rtt_p99_us;
            println!(
                "fanin {core} x{connections}: {:.0} req/s pipelined ({} ok, {} err), \
                 closed-loop p50 {:.0}us p99 {:.0}us",
                rps[i], throughput.ok, throughput.errors, rtt.rtt_p50_us, rtt.rtt_p99_us
            );
        }
        rows.push(crate::util::bench::FanInComparison {
            connections,
            requests_per_conn,
            threaded_rps: rps[0],
            evented_rps: rps[1],
            threaded_rtt_p99_us: rtt_p99[0],
            evented_rtt_p99_us: rtt_p99[1],
        });
    }
    Ok(rows)
}

/// One service pass over a connection: read + settle responses, top up
/// the send window, flush, reconcile poller interest, finish when all
/// responses are in.
#[allow(clippy::too_many_arguments)]
fn pump(
    conn: &mut FConn,
    idx: usize,
    model: &str,
    frame_len: usize,
    cfg: &FanInConfig,
    poller: &mut Poller,
    ok: &mut u64,
    errors: &mut u64,
    rtts_ns: &mut Vec<u64>,
    live: &mut usize,
) -> Result<(), String> {
    // Read and settle.
    loop {
        match conn.decoder.read_from(&mut conn.stream) {
            Ok(0) => {
                if conn.recvd < cfg.requests_per_conn {
                    return Err(format!(
                        "fan-in conn {idx}: server closed after {}/{} responses",
                        conn.recvd, cfg.requests_per_conn
                    ));
                }
                break;
            }
            Ok(_) => {}
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("fan-in conn {idx}: read: {e}")),
        }
        loop {
            match conn.decoder.next() {
                Ok(Some(msg)) => {
                    let sent_at = conn
                        .inflight
                        .pop_front()
                        .ok_or_else(|| format!("fan-in conn {idx}: unsolicited response"))?;
                    rtts_ns.push(sent_at.elapsed().as_nanos() as u64);
                    conn.recvd += 1;
                    match msg {
                        Msg::InferOk { .. } => *ok += 1,
                        Msg::InferErr { .. } => *errors += 1,
                        other => {
                            return Err(format!(
                                "fan-in conn {idx}: unexpected response kind {other:?}"
                            ))
                        }
                    }
                }
                Ok(None) => break,
                Err(e) => return Err(format!("fan-in conn {idx}: protocol: {e}")),
            }
        }
    }
    // Top up the window.
    while conn.sent < cfg.requests_per_conn && conn.inflight.len() < cfg.window {
        let msg = Msg::InferRequest {
            id: conn.sent as u64,
            model: model.to_string(),
            frame: frame_for(cfg.seed, idx, conn.sent, frame_len),
            deadline_us: 0,
            class: 0,
        };
        msg.encode_into(&mut conn.out)
            .map_err(|e| format!("fan-in conn {idx}: encode: {e}"))?;
        conn.inflight.push_back(Instant::now());
        conn.sent += 1;
    }
    // Flush.
    while conn.out_pos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => return Err(format!("fan-in conn {idx}: write returned 0")),
            Ok(n) => conn.out_pos += n,
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("fan-in conn {idx}: write: {e}")),
        }
    }
    if conn.out_pos >= conn.out.len() {
        conn.out.clear();
        conn.out_pos = 0;
    }
    // Finish or re-arm.
    if conn.recvd >= cfg.requests_per_conn {
        conn.done = true;
        if conn.registered {
            let _ = poller.deregister(conn.stream.as_raw_fd());
            conn.registered = false;
        }
        let _ = conn.stream.shutdown(Shutdown::Both);
        *live -= 1;
        return Ok(());
    }
    let want = Interest {
        readable: !conn.inflight.is_empty(),
        writable: conn.out_pos < conn.out.len(),
    };
    let fd = conn.stream.as_raw_fd();
    if !conn.registered {
        poller
            .register(fd, idx, want)
            .map_err(|e| format!("fan-in conn {idx}: register: {e}"))?;
        conn.registered = true;
        conn.interest = want;
    } else if want != conn.interest {
        poller
            .modify(fd, idx, want)
            .map_err(|e| format!("fan-in conn {idx}: rearm: {e}"))?;
        conn.interest = want;
    }
    Ok(())
}
