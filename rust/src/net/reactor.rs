//! Std-only readiness poller over raw fds — the foundation of the
//! evented network core (DESIGN.md §10).
//!
//! Zero new dependencies: on Linux this is epoll reached through thin
//! `extern "C"` declarations of the three syscall wrappers the C library
//! std already links against (`epoll_create1` / `epoll_ctl` /
//! `epoll_wait`); every other Unix gets a level-triggered `poll(2)`
//! wrapper over the same API. Both backends are **level-triggered**: a
//! socket that still has readable bytes (or writable space) keeps
//! reporting, so the reactor never needs edge-triggered drain loops to
//! be correct — only to be fast.
//!
//! [`Waker`] is the poller-based wakeup that replaces the threaded
//! core's loopback self-connect shutdown hack: a `socketpair(2)` (via
//! `std::os::unix::net::UnixStream::pair`, still std-only) whose read
//! end is registered like any other fd. Worker threads and the shutdown
//! path write one byte to interrupt `wait` from outside the loop.

use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

/// What a registration wants to hear about. Level-triggered in both
/// backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
}

/// One readiness report. Error/hangup conditions surface as both
/// `readable` and `writable` so the owner discovers the failure from
/// the I/O call itself — the same contract epoll gives `EPOLLERR`.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// A level-triggered readiness poller over raw fds. The caller maps
/// tokens to connections; the poller never owns an fd it watches.
pub struct Poller {
    backend: Backend,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller {
            backend: Backend::new()?,
        })
    }

    /// Start watching `fd` under `token`. One registration per fd.
    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.backend.register(fd, token, interest)
    }

    /// Change what an already-registered fd is watched for.
    pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.backend.modify(fd, token, interest)
    }

    /// Stop watching `fd`. Must be called before the fd closes, or a
    /// reused fd number could alias a stale registration.
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.backend.deregister(fd)
    }

    /// Block until readiness or `timeout` (None = forever), appending
    /// into `events` (cleared first). `Ok` with an empty vec is a
    /// timeout. `EINTR` retries internally.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        self.backend.wait(events, timeout)
    }
}

/// Clamp an optional timeout to the millisecond `int` the kernel APIs
/// take, rounding up so sub-millisecond deadlines never busy-spin.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(t) => {
            let ms = t.as_millis();
            if ms == 0 && !t.is_zero() {
                1
            } else {
                ms.min(i32::MAX as u128) as i32
            }
        }
    }
}

/// Wakes a [`Poller`] from outside its loop: one byte down a
/// nonblocking socketpair. Coalescing is free — once the pipe holds an
/// unread byte, further wakes hit `WouldBlock` and are dropped, which
/// is exactly right for an "attention requested" edge.
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        // &UnixStream implements Write; WouldBlock means a wake is
        // already pending, any other failure means the reactor is gone —
        // both are safe to ignore.
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// The read half the reactor registers; `drain` eats the pending bytes
/// so the level-triggered poller stops reporting it.
pub struct WakeReader {
    rx: UnixStream,
}

impl WakeReader {
    pub fn as_raw_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    pub fn drain(&self) {
        let mut sink = [0u8; 64];
        while let Ok(n) = (&self.rx).read(&mut sink) {
            if n < sink.len() {
                break;
            }
        }
    }
}

/// Build a connected waker pair (both ends nonblocking).
pub fn waker() -> io::Result<(Waker, WakeReader)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeReader { rx }))
}

// ---------------------------------------------------------------------
// Linux backend: epoll via extern "C" shims (no libc crate — these
// symbols come from the C library std already links).
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
use epoll::Backend;

#[cfg(target_os = "linux")]
mod epoll {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    /// Kernel ABI: packed on x86-64 (the one arch where the natural
    /// layout would differ), natural layout elsewhere.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn cvt(ret: c_int) -> io::Result<c_int> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    pub struct Backend {
        epfd: c_int,
        buf: Vec<EpollEvent>,
    }

    impl Backend {
        pub fn new() -> io::Result<Backend> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Backend {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(
            &mut self,
            op: c_int,
            fd: RawFd,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: mask(interest),
                data: token as u64,
            };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) }).map(|_| ())
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            cvt(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) }).map(|_| ())
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            let n = loop {
                let ret = unsafe {
                    epoll_wait(
                        self.epfd,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as c_int,
                        timeout_ms(timeout),
                    )
                };
                match cvt(ret) {
                    Ok(n) => break n as usize,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            for raw in &self.buf[..n] {
                let bits = raw.events;
                let err = bits & (EPOLLERR | EPOLLHUP) != 0;
                events.push(Event {
                    token: raw.data as usize,
                    readable: bits & EPOLLIN != 0 || err,
                    writable: bits & EPOLLOUT != 0 || err,
                });
            }
            Ok(())
        }
    }

    impl Drop for Backend {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Portable Unix backend: level-triggered poll(2) over a registration
// table. O(n) per wait, which is fine as the fallback path.
// ---------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
use poll_backend::Backend;

#[cfg(all(unix, not(target_os = "linux")))]
mod poll_backend {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::os::raw::{c_int, c_short, c_ulong};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }

    fn mask(interest: Interest) -> c_short {
        let mut m = 0;
        if interest.readable {
            m |= POLLIN;
        }
        if interest.writable {
            m |= POLLOUT;
        }
        m
    }

    #[derive(Default)]
    pub struct Backend {
        fds: Vec<PollFd>,
        tokens: Vec<usize>,
    }

    impl Backend {
        pub fn new() -> io::Result<Backend> {
            Ok(Backend::default())
        }

        fn position(&self, fd: RawFd) -> Option<usize> {
            self.fds.iter().position(|p| p.fd == fd)
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            if self.position(fd).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    "fd already registered",
                ));
            }
            self.fds.push(PollFd {
                fd,
                events: mask(interest),
                revents: 0,
            });
            self.tokens.push(token);
            Ok(())
        }

        pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let i = self
                .position(fd)
                .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
            self.fds[i].events = mask(interest);
            self.tokens[i] = token;
            Ok(())
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            let i = self
                .position(fd)
                .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
            self.fds.swap_remove(i);
            self.tokens.swap_remove(i);
            Ok(())
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            let n = loop {
                let ret = unsafe {
                    poll(
                        self.fds.as_mut_ptr(),
                        self.fds.len() as c_ulong,
                        timeout_ms(timeout),
                    )
                };
                if ret < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                break ret as usize;
            };
            if n == 0 {
                return Ok(());
            }
            for (p, &token) in self.fds.iter().zip(self.tokens.iter()) {
                let bits = p.revents;
                if bits == 0 {
                    continue;
                }
                let err = bits & (POLLERR | POLLHUP | POLLNVAL) != 0;
                events.push(Event {
                    token,
                    readable: bits & POLLIN != 0 || err,
                    writable: bits & POLLOUT != 0 || err,
                });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn waker_wakes_and_coalesces() {
        let mut poller = Poller::new().unwrap();
        let (waker, reader) = waker().unwrap();
        poller.register(reader.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        // No wake yet: a short wait times out empty.
        poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert!(events.is_empty());
        waker.wake();
        waker.wake(); // coalesces
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        reader.drain();
        poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert!(events.is_empty(), "drained waker must go quiet");
    }

    #[test]
    fn listener_readability_and_interest_changes() {
        let mut poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller
            .register(listener.as_raw_fd(), 1, Interest::READ)
            .unwrap();
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut events = Vec::new();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable));
        // A connected socket with empty send buffer is writable.
        let (conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();
        poller
            .register(conn.as_raw_fd(), 2, Interest::WRITE)
            .unwrap();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 2 && e.writable));
        // Modify to read-only: no more writable reports for token 2.
        poller
            .modify(conn.as_raw_fd(), 2, Interest::READ)
            .unwrap();
        poller
            .wait(&mut events, Some(Duration::from_millis(20)))
            .unwrap();
        assert!(!events.iter().any(|e| e.token == 2));
        poller.deregister(conn.as_raw_fd()).unwrap();
        poller.deregister(listener.as_raw_fd()).unwrap();
    }
}
