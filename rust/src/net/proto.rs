//! Versioned, length-prefixed binary wire protocol for the TCP serving
//! front-end (DESIGN.md §8).
//!
//! Every frame on the wire is `u32 BE body length | body`, where the body
//! starts with a protocol version byte and a message kind byte. Integers
//! are big-endian; strings are `u16 BE length | UTF-8 bytes`; activation
//! and logit vectors are `u32 BE count | i64 BE * count` — lossless for
//! the accumulator-scale `i64` values the coordinator serves, which is
//! what makes the TCP path byte-identical to in-process serving.
//!
//! Design constraints, all pinned by tests (`tests/net_serving.rs`):
//!
//! * **zero dependencies** — hand-rolled encode/decode over
//!   `std::io::{Read, Write}`; no serde, no tokio;
//! * **malformed input must never panic the server** — every decode path
//!   is bounds-checked and returns a typed [`ProtoError`]; oversized
//!   length prefixes are rejected *before* any allocation
//!   ([`MAX_BODY`]);
//! * **typed error codes** ([`ErrorCode`]) map 1:1 onto coordinator
//!   rejection reasons ([`ErrorCode::from_reject`]), so a TCP client can
//!   distinguish backpressure from a bad frame from a drain without
//!   string matching.

use std::io::{self, Read, Write};

/// Current protocol version. Decoding accepts [`PROTO_V1`] through this
/// value; anything newer fails with [`ProtoError::BadVersion`] — version
/// skew must be loud, not silently misparsed.
///
/// Version 2 adds optional per-request SLO fields: `deadline_us`/`class`
/// on `InferRequest`, `predicted_cycles`/`slo_met` on `InferOk`. The
/// encoder stamps each message with the **lowest version that can carry
/// it** — a deadline-free request and its reply are byte-identical to
/// version 1, so old clients interoperate with a v2 server (and vice
/// versa) as long as nobody sets the new fields.
pub const PROTO_VERSION: u8 = 2;

/// Oldest version this build still decodes.
pub const PROTO_V1: u8 = 1;

/// Hard cap on a frame body (bytes), enforced before the body is
/// allocated: a hostile or corrupt length prefix must not let a single
/// connection allocate unbounded memory. 1 MiB comfortably covers the
/// largest zoo model's input frame (`24*24*8` i64 values ≈ 36 KiB) plus
/// headers.
pub const MAX_BODY: u32 = 1 << 20;

const KIND_INFER_REQUEST: u8 = 0x01;
const KIND_INFER_OK: u8 = 0x02;
const KIND_INFER_ERR: u8 = 0x03;
const KIND_LIST_MODELS: u8 = 0x04;
const KIND_MODEL_LIST: u8 = 0x05;
const KIND_METRICS_TEXT: u8 = 0x06;
const KIND_METRICS_TEXT_REPLY: u8 = 0x07;

/// Typed protocol error codes, one per coordinator rejection reason
/// (DESIGN.md §8). The mapping is a serving contract pinned by
/// `tests/net_serving.rs`: each code reconciles with exactly one
/// coordinator counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Every shard queue in the model's group was full (backpressure
    /// spill exhausted) — reconciles with intake `rejected`.
    QueueFull = 1,
    /// The request was accepted but its frame failed validation (wrong
    /// length, out-of-grid values) — reconciles with shard `errored`.
    InvalidFrame = 2,
    /// No route for the requested model id — reconciles with `unrouted`.
    UnknownModel = 3,
    /// The server is draining (or has drained): intake is closed, new
    /// requests are refused, in-flight ones still complete.
    Draining = 4,
    /// The peer violated the wire protocol (bad frame, bad version,
    /// oversized body, unexpected message kind). Net-layer only — no
    /// coordinator counter moves.
    Malformed = 5,
    /// Admission control predicted the request's completion past its
    /// deadline and shed it before queueing — reconciles with intake
    /// `shed`. Cheap shed beats late work (DESIGN.md §12).
    SloMiss = 6,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::QueueFull),
            2 => Some(ErrorCode::InvalidFrame),
            3 => Some(ErrorCode::UnknownModel),
            4 => Some(ErrorCode::Draining),
            5 => Some(ErrorCode::Malformed),
            6 => Some(ErrorCode::SloMiss),
            _ => None,
        }
    }

    /// Classify a coordinator rejection message into its wire code — the
    /// 1:1 mapping the front-end applies to every `Err` the coordinator
    /// returns (`Server::submit_to` at submit time, `Pending::wait` for
    /// per-request validation errors). Frame-validation failures are the
    /// only per-request errors the shards emit, so everything that is
    /// neither backpressure, an unknown route, nor a shutdown is
    /// [`ErrorCode::InvalidFrame`].
    ///
    /// Known trade-off: this matches the coordinator's error *strings*
    /// rather than a typed reject enum (the coordinator API is
    /// stringly-typed end to end). Drift is caught loudly: the
    /// reconciliation tests in `tests/net_serving.rs` assert each code
    /// against the corresponding coordinator counter, so a reworded
    /// message fails CI instead of silently reclassifying.
    pub fn from_reject(msg: &str) -> ErrorCode {
        // Prefix/exact matches only, and the unknown-route message first:
        // it is the one message that embeds a client-chosen string (the
        // model id), so looser contains() heuristics after it must never
        // get a chance to match id contents like "backpressure".
        if msg.starts_with("no route for model") {
            ErrorCode::UnknownModel
        } else if msg.starts_with("backpressure") {
            ErrorCode::QueueFull
        } else if msg.starts_with("slo miss") {
            ErrorCode::SloMiss
        } else if msg == "server stopped" || msg == "server dropped request" {
            ErrorCode::Draining
        } else {
            ErrorCode::InvalidFrame
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            ErrorCode::QueueFull => "queue-full",
            ErrorCode::InvalidFrame => "invalid-frame",
            ErrorCode::UnknownModel => "unknown-model",
            ErrorCode::Draining => "draining",
            ErrorCode::Malformed => "malformed",
            ErrorCode::SloMiss => "slo-miss",
        };
        f.write_str(name)
    }
}

/// A decode failure. Malformed peers get one of these instead of a panic;
/// the server answers with [`ErrorCode::Malformed`] and closes the
/// connection (the stream cannot be resynchronized once framing is lost).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Underlying socket/stream error.
    Io(String),
    /// The stream ended in the middle of a frame (length prefix or body).
    Truncated,
    /// The length prefix exceeds [`MAX_BODY`]; rejected before allocation.
    Oversized(u32),
    /// The body's version byte is outside the accepted
    /// [`PROTO_V1`]..=[`PROTO_VERSION`] window.
    BadVersion(u8),
    /// Structurally invalid body (unknown kind, short payload, bad UTF-8,
    /// inconsistent counts, trailing bytes).
    Malformed(String),
    /// An encode-side collection exceeds its wire count prefix (`u16` for
    /// the model list, `u32` for activation vectors). Encoding refuses
    /// rather than letting `as` silently wrap the count and desynchronize
    /// every later byte of the frame.
    CountOverflow {
        field: &'static str,
        count: usize,
        max: u64,
    },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io error: {e}"),
            ProtoError::Truncated => write!(f, "truncated frame"),
            ProtoError::Oversized(n) => {
                write!(f, "oversized frame body ({n} bytes > {MAX_BODY} max)")
            }
            ProtoError::BadVersion(v) => {
                write!(
                    f,
                    "unsupported protocol version {v} (expected {PROTO_V1}..={PROTO_VERSION})"
                )
            }
            ProtoError::Malformed(m) => write!(f, "malformed frame: {m}"),
            ProtoError::CountOverflow { field, count, max } => {
                write!(f, "{field} count {count} exceeds the wire maximum {max}")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

/// One protocol message. Request ids are caller-chosen and echoed back
/// verbatim; the server answers a connection's requests **in request
/// order**, so a pipelining client may key on order or on id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Msg {
    /// Client → server: run `frame` through `model`'s shard group.
    ///
    /// `deadline_us`/`class` are the v2 SLO extension: a completion
    /// deadline in microseconds of modelled time (0 = none — best
    /// effort) and a client-chosen priority class for per-class
    /// reporting. A request with both at their defaults encodes as a v1
    /// frame, byte-identical to the pre-SLO wire.
    InferRequest {
        id: u64,
        model: String,
        frame: Vec<i64>,
        deadline_us: u64,
        class: u8,
    },
    /// Server → client: successful inference (accumulator-scale logits).
    ///
    /// `predicted_cycles`/`slo_met` are the v2 SLO extension, set only
    /// for deadline-bearing requests: the admission-time completion
    /// prediction and whether it fit the deadline budget. Both at their
    /// defaults encode as a v1 frame.
    InferOk {
        id: u64,
        argmax: u32,
        sim_latency_cycles: u64,
        logits: Vec<i64>,
        predicted_cycles: u64,
        slo_met: bool,
    },
    /// Server → client: typed refusal (id 0 when the failing request
    /// could not be decoded).
    InferErr {
        id: u64,
        code: ErrorCode,
        message: String,
    },
    /// Client → server: what models does this server route?
    ListModels,
    /// Server → client: `(model id, input frame length)` per group, in
    /// route order — enough for a client to synthesize valid traffic.
    ModelList { models: Vec<(String, u32)> },
    /// Client → server: render the current metrics in Prometheus text
    /// exposition format (DESIGN.md §13). No fields; the reply snapshots
    /// at dispatch time.
    MetricsText,
    /// Server → client: one Prometheus text page. The payload rides a
    /// u32 length prefix (not `str16`): a many-model exposition page can
    /// exceed the 64 KiB a `u16` prefix carries.
    MetricsTextReply { text: String },
}

impl Msg {
    /// Encode into a complete wire frame (length prefix included).
    ///
    /// Fallible by design: a frame whose counts do not fit their wire
    /// prefixes ([`ProtoError::CountOverflow`]) or whose body exceeds
    /// [`MAX_BODY`] ([`ProtoError::Oversized`]) is refused here, at the
    /// sender — the old `as u16`/`as u32` casts would silently wrap the
    /// count and emit a frame the peer misparses.
    pub fn encode(&self) -> Result<Vec<u8>, ProtoError> {
        let mut out = Vec::with_capacity(36);
        self.encode_into(&mut out)?;
        Ok(out)
    }

    /// Append a complete wire frame to `out` (length prefix included),
    /// without allocating a per-frame `Vec`: the body is written in place
    /// after a 4-byte placeholder, then the prefix is patched. On any
    /// encode refusal `out` is rewound to its original length — a partial
    /// frame never survives in the buffer. This is the hot path for the
    /// evented core's per-connection write buffer.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> Result<(), ProtoError> {
        let frame_start = out.len();
        out.extend_from_slice(&[0u8; 4]);
        let body_start = out.len();
        let wrote = self.encode_body(out);
        let body_len = out.len() - body_start;
        let checked = wrote.and_then(|()| {
            if body_len as u64 > MAX_BODY as u64 {
                Err(ProtoError::Oversized(
                    u32::try_from(body_len).unwrap_or(u32::MAX),
                ))
            } else {
                Ok(())
            }
        });
        if let Err(e) = checked {
            out.truncate(frame_start);
            return Err(e);
        }
        out[frame_start..body_start].copy_from_slice(&(body_len as u32).to_be_bytes());
        Ok(())
    }

    /// The lowest protocol version that can carry this message: v2 only
    /// when an SLO extension field is set, so deadline-free traffic stays
    /// byte-identical to the v1 wire and old peers decode it unchanged.
    pub fn wire_version(&self) -> u8 {
        match self {
            Msg::InferRequest { deadline_us, class, .. } if *deadline_us != 0 || *class != 0 => {
                PROTO_VERSION
            }
            Msg::InferOk {
                predicted_cycles,
                slo_met,
                ..
            } if *predicted_cycles != 0 || *slo_met => PROTO_VERSION,
            _ => PROTO_V1,
        }
    }

    fn encode_body(&self, body: &mut Vec<u8>) -> Result<(), ProtoError> {
        let version = self.wire_version();
        body.push(version);
        match self {
            Msg::InferRequest {
                id,
                model,
                frame,
                deadline_us,
                class,
            } => {
                body.push(KIND_INFER_REQUEST);
                push_u64(body, *id);
                push_str16(body, model);
                push_vec_i64(body, frame, "frame")?;
                if version >= 2 {
                    push_u64(body, *deadline_us);
                    body.push(*class);
                }
            }
            Msg::InferOk {
                id,
                argmax,
                sim_latency_cycles,
                logits,
                predicted_cycles,
                slo_met,
            } => {
                body.push(KIND_INFER_OK);
                push_u64(body, *id);
                push_u32(body, *argmax);
                push_u64(body, *sim_latency_cycles);
                push_vec_i64(body, logits, "logits")?;
                if version >= 2 {
                    push_u64(body, *predicted_cycles);
                    body.push(u8::from(*slo_met));
                }
            }
            Msg::InferErr { id, code, message } => {
                body.push(KIND_INFER_ERR);
                push_u64(body, *id);
                body.push(code.as_u8());
                push_str16(body, message);
            }
            Msg::ListModels => body.push(KIND_LIST_MODELS),
            Msg::ModelList { models } => {
                body.push(KIND_MODEL_LIST);
                if models.len() > u16::MAX as usize {
                    return Err(ProtoError::CountOverflow {
                        field: "model list",
                        count: models.len(),
                        max: u16::MAX as u64,
                    });
                }
                push_u16(body, models.len() as u16);
                for (id, input_len) in models {
                    push_str16(body, id);
                    push_u32(body, *input_len);
                }
            }
            Msg::MetricsText => body.push(KIND_METRICS_TEXT),
            Msg::MetricsTextReply { text } => {
                body.push(KIND_METRICS_TEXT_REPLY);
                push_bytes32(body, text.as_bytes(), "metrics text")?;
            }
        }
        Ok(())
    }

    /// Decode a frame body (everything after the length prefix). The body
    /// must be consumed exactly — trailing bytes are malformed.
    pub fn decode(body: &[u8]) -> Result<Msg, ProtoError> {
        let mut cur = Cur { b: body, i: 0 };
        let version = cur.u8()?;
        if !(PROTO_V1..=PROTO_VERSION).contains(&version) {
            return Err(ProtoError::BadVersion(version));
        }
        let kind = cur.u8()?;
        let msg = match kind {
            KIND_INFER_REQUEST => {
                let id = cur.u64()?;
                let model = cur.str16()?;
                let frame = cur.vec_i64()?;
                let (deadline_us, class) = if version >= 2 {
                    (cur.u64()?, cur.u8()?)
                } else {
                    (0, 0)
                };
                Msg::InferRequest {
                    id,
                    model,
                    frame,
                    deadline_us,
                    class,
                }
            }
            KIND_INFER_OK => {
                let id = cur.u64()?;
                let argmax = cur.u32()?;
                let sim_latency_cycles = cur.u64()?;
                let logits = cur.vec_i64()?;
                let (predicted_cycles, slo_met) = if version >= 2 {
                    let p = cur.u64()?;
                    let met = match cur.u8()? {
                        0 => false,
                        1 => true,
                        other => {
                            return Err(ProtoError::Malformed(format!(
                                "slo_met flag must be 0 or 1, got {other}"
                            )))
                        }
                    };
                    (p, met)
                } else {
                    (0, false)
                };
                Msg::InferOk {
                    id,
                    argmax,
                    sim_latency_cycles,
                    logits,
                    predicted_cycles,
                    slo_met,
                }
            }
            KIND_INFER_ERR => {
                let id = cur.u64()?;
                let raw = cur.u8()?;
                let code = ErrorCode::from_u8(raw)
                    .ok_or_else(|| ProtoError::Malformed(format!("unknown error code {raw}")))?;
                Msg::InferErr {
                    id,
                    code,
                    message: cur.str16()?,
                }
            }
            KIND_LIST_MODELS => Msg::ListModels,
            KIND_MODEL_LIST => {
                let n = cur.u16()? as usize;
                let mut models = Vec::with_capacity(n.min(256));
                for _ in 0..n {
                    let id = cur.str16()?;
                    let input_len = cur.u32()?;
                    models.push((id, input_len));
                }
                Msg::ModelList { models }
            }
            KIND_METRICS_TEXT => Msg::MetricsText,
            KIND_METRICS_TEXT_REPLY => {
                let n = cur.u32()? as usize;
                let bytes = cur.take(n)?;
                let text = String::from_utf8(bytes.to_vec()).map_err(|_| {
                    ProtoError::Malformed("metrics text is not valid UTF-8".into())
                })?;
                Msg::MetricsTextReply { text }
            }
            other => {
                return Err(ProtoError::Malformed(format!("unknown message kind {other:#04x}")))
            }
        };
        cur.done()?;
        Ok(msg)
    }
}

/// Read one frame from `r`. `Ok(None)` means the stream ended cleanly at
/// a frame boundary (the peer closed); EOF mid-frame is
/// [`ProtoError::Truncated`]. Oversized length prefixes fail before the
/// body is allocated.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Msg>, ProtoError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(ProtoError::Truncated)
                }
            }
            Ok(n) => got += n,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e.to_string())),
        }
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_BODY {
        return Err(ProtoError::Oversized(len));
    }
    if len < 2 {
        return Err(ProtoError::Malformed(format!(
            "body length {len} shorter than the version+kind header"
        )));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            ProtoError::Truncated
        } else {
            ProtoError::Io(e.to_string())
        }
    })?;
    Msg::decode(&body).map(Some)
}

/// Write one complete frame (and flush, so a buffered writer's pipelined
/// responses reach the socket per message). Fails with the encode-time
/// [`ProtoError`]s before any byte hits the wire — a half-frame must
/// never reach the peer.
pub fn write_frame<W: Write>(w: &mut W, msg: &Msg) -> Result<(), ProtoError> {
    queue_frame(w, msg)?;
    w.flush().map_err(|e| ProtoError::Io(e.to_string()))
}

/// Write one complete frame **without flushing** — the batch-flush half
/// of the writer protocol. A pipelining writer queues frames with this
/// and flushes only when its queue is momentarily empty, so a burst of
/// responses coalesces into few syscalls instead of one per message
/// (the old flush-per-frame behaviour defeated write batching under
/// pipelining). Encode refusals still fail before any byte is written.
pub fn queue_frame<W: Write>(w: &mut W, msg: &Msg) -> Result<(), ProtoError> {
    let bytes = msg.encode()?;
    w.write_all(&bytes).map_err(|e| ProtoError::Io(e.to_string()))
}

// -- incremental decoding ----------------------------------------------

/// Incremental frame decoder for nonblocking readers: a growable
/// scratch buffer that accepts whatever bytes the socket has (any split
/// points, including mid-prefix and mid-body) and yields complete
/// [`Msg`]s as they materialize. Bodies are decoded **in place** from
/// the buffer slice — no per-frame body `Vec` is allocated, unlike the
/// blocking [`read_frame`] path.
///
/// Identity contract (pinned by a property test in
/// `tests/net_serving.rs`): feeding a byte stream through
/// [`FrameDecoder`] at *any* split points yields exactly the sequence of
/// messages (or the error) that [`read_frame`] produces on the same
/// stream, and adversarial splits/corruption never panic.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    /// Scratch bytes; only `start..end` is valid, the tail beyond `end`
    /// is previously-zeroed spare capacity for the next `read_from`.
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

/// One `read(2)`'s worth of spare tail maintained by `read_from`.
const READ_CHUNK: usize = 16 * 1024;
/// Consumed prefix beyond which `next` compacts instead of growing.
const COMPACT_AT: usize = 64 * 1024;
/// High-water mark: once drained, a buffer grown past this (by a large
/// frame) is released back to the allocator so 10k idle connections do
/// not pin 10k max-size scratch buffers.
const RETAIN_CAP: usize = 64 * 1024;

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Bytes buffered but not yet consumed by [`next`](Self::next).
    pub fn buffered(&self) -> usize {
        self.end - self.start
    }

    /// True when the stream ended mid-frame: EOF with buffered bytes is
    /// [`ProtoError::Truncated`] by the same rule as [`read_frame`].
    pub fn has_partial(&self) -> bool {
        self.end > self.start
    }

    /// Append raw bytes (test harness / in-memory feeding).
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        self.compact();
        if self.buf.len() - self.end < bytes.len() {
            self.buf.resize(self.end + bytes.len(), 0);
        }
        self.buf[self.end..self.end + bytes.len()].copy_from_slice(bytes);
        self.end += bytes.len();
    }

    /// Issue **one** `read` into the spare tail. `Ok(0)` is EOF;
    /// `WouldBlock`/`Interrupted` are returned as-is for the caller's
    /// readiness loop to interpret.
    pub fn read_from<R: Read>(&mut self, r: &mut R) -> io::Result<usize> {
        self.compact();
        if self.buf.len() - self.end < READ_CHUNK {
            // Grow (zeroing only the newly-exposed tail once); length is
            // the high-water mark, `end` tracks the valid prefix.
            self.buf.resize(self.end + READ_CHUNK, 0);
        }
        let n = r.read(&mut self.buf[self.end..])?;
        self.end += n;
        Ok(n)
    }

    /// Decode the next complete frame, if the buffer holds one.
    /// `Ok(None)` means "need more bytes"; errors are fatal to the
    /// stream (framing cannot be resynchronized), matching
    /// [`read_frame`]'s classification exactly.
    pub fn next(&mut self) -> Result<Option<Msg>, ProtoError> {
        let avail = self.end - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let len_bytes: [u8; 4] = self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_be_bytes(len_bytes);
        if len > MAX_BODY {
            return Err(ProtoError::Oversized(len));
        }
        if len < 2 {
            return Err(ProtoError::Malformed(format!(
                "body length {len} shorter than the version+kind header"
            )));
        }
        let total = 4 + len as usize;
        if avail < total {
            return Ok(None);
        }
        let msg = Msg::decode(&self.buf[self.start + 4..self.start + total]);
        self.start += total;
        self.compact();
        msg.map(Some)
    }

    /// Drop the consumed prefix: reset when drained (releasing an
    /// oversized scratch buffer), slide when the dead prefix has grown
    /// past [`COMPACT_AT`].
    fn compact(&mut self) {
        if self.start == self.end {
            self.start = 0;
            self.end = 0;
            if self.buf.len() > RETAIN_CAP {
                self.buf = Vec::new();
            }
        } else if self.start >= COMPACT_AT {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
    }
}

// -- encode helpers ----------------------------------------------------

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn push_str16(out: &mut Vec<u8>, s: &str) {
    // Truncate (at a char boundary) rather than let the u16 length
    // prefix wrap and desynchronize the frame: model ids and error
    // messages are the only strings on the wire, both far below the cap
    // in practice, and a consistent-but-shortened frame beats a corrupt
    // one in a release build.
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    push_u16(out, end as u16);
    out.extend_from_slice(&s.as_bytes()[..end]);
}

fn push_bytes32(out: &mut Vec<u8>, bytes: &[u8], field: &'static str) -> Result<(), ProtoError> {
    if bytes.len() > u32::MAX as usize {
        return Err(ProtoError::CountOverflow {
            field,
            count: bytes.len(),
            max: u32::MAX as u64,
        });
    }
    push_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
    Ok(())
}

fn push_vec_i64(out: &mut Vec<u8>, xs: &[i64], field: &'static str) -> Result<(), ProtoError> {
    if xs.len() > u32::MAX as usize {
        return Err(ProtoError::CountOverflow {
            field,
            count: xs.len(),
            max: u32::MAX as u64,
        });
    }
    push_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_be_bytes());
    }
    Ok(())
}

// -- decode cursor -----------------------------------------------------

/// Bounds-checked cursor over one frame body: every read validates the
/// remaining length first, so hostile bodies can under-declare or
/// over-declare counts without ever causing a panic or an oversized
/// allocation.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.b.len() - self.i < n {
            return Err(ProtoError::Malformed(format!(
                "body too short: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtoError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str16(&mut self) -> Result<String, ProtoError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtoError::Malformed("string is not valid UTF-8".into()))
    }

    fn vec_i64(&mut self) -> Result<Vec<i64>, ProtoError> {
        let n = self.u32()? as usize;
        // The declared count must fit in the bytes actually present —
        // checked before allocating, so a lying prefix cannot balloon.
        let bytes = self.take(n.checked_mul(8).ok_or_else(|| {
            ProtoError::Malformed("i64 vector count overflows".into())
        })?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_be_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(ProtoError::Malformed(format!(
                "{} trailing bytes after message",
                self.b.len() - self.i
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let bytes = msg.encode().expect("encode failed");
        let mut cursor = &bytes[..];
        read_frame(&mut cursor)
            .expect("roundtrip decode failed")
            .expect("unexpected EOF")
    }

    #[test]
    fn every_kind_roundtrips() {
        let msgs = [
            Msg::InferRequest {
                id: 7,
                model: "digits_cnn".into(),
                frame: vec![-127, 0, 127, 5],
                deadline_us: 0,
                class: 0,
            },
            Msg::InferRequest {
                id: 8,
                model: "digits_cnn".into(),
                frame: vec![1, 2],
                deadline_us: 2_500,
                class: 3,
            },
            Msg::InferOk {
                id: 7,
                argmax: 3,
                sim_latency_cycles: 12345,
                logits: vec![i64::MIN, -1, 0, i64::MAX],
                predicted_cycles: 0,
                slo_met: false,
            },
            Msg::InferOk {
                id: 8,
                argmax: 1,
                sim_latency_cycles: 99,
                logits: vec![4, 5],
                predicted_cycles: 70_000,
                slo_met: true,
            },
            Msg::InferErr {
                id: 9,
                code: ErrorCode::QueueFull,
                message: "backpressure: all shard queues full".into(),
            },
            Msg::ListModels,
            Msg::ModelList {
                models: vec![("a".into(), 64), ("b".into(), 144)],
            },
            Msg::MetricsText,
            Msg::MetricsTextReply {
                text: "# TYPE cnn_flow_workers gauge\ncnn_flow_workers 4\n".into(),
            },
        ];
        for m in &msgs {
            assert_eq!(&roundtrip(m), m);
        }
    }

    /// The version-bump compatibility contract: messages without SLO
    /// fields emit v1 bodies **byte-identical** to the pre-v2 wire (old
    /// peers decode them unchanged), while SLO-bearing messages emit v2;
    /// both decode back exactly.
    #[test]
    fn deadline_free_messages_stay_on_the_v1_wire() {
        let plain = Msg::InferRequest {
            id: 7,
            model: "m".into(),
            frame: vec![1, 2, 3],
            deadline_us: 0,
            class: 0,
        };
        let bytes = plain.encode().unwrap();
        assert_eq!(bytes[4], PROTO_V1, "deadline-free request must be v1");
        // Reconstruct the exact pre-v2 encoding by hand and compare.
        let mut legacy = vec![PROTO_V1, KIND_INFER_REQUEST];
        push_u64(&mut legacy, 7);
        push_str16(&mut legacy, "m");
        push_vec_i64(&mut legacy, &[1, 2, 3], "frame").unwrap();
        let mut framed = (legacy.len() as u32).to_be_bytes().to_vec();
        framed.extend_from_slice(&legacy);
        assert_eq!(bytes, framed, "v1 byte-identity broken");

        let tagged = Msg::InferRequest {
            id: 7,
            model: "m".into(),
            frame: vec![1, 2, 3],
            deadline_us: 1,
            class: 0,
        };
        assert_eq!(tagged.encode().unwrap()[4], PROTO_VERSION);
        assert_eq!(roundtrip(&tagged), tagged);

        let ok = Msg::InferOk {
            id: 7,
            argmax: 0,
            sim_latency_cycles: 5,
            logits: vec![9],
            predicted_cycles: 0,
            slo_met: false,
        };
        assert_eq!(ok.encode().unwrap()[4], PROTO_V1, "plain reply must be v1");
        assert_eq!(Msg::ListModels.encode().unwrap()[4], PROTO_V1);
    }

    #[test]
    fn empty_vectors_and_strings_roundtrip() {
        let m = Msg::InferRequest {
            id: 0,
            model: String::new(),
            frame: Vec::new(),
            deadline_us: 0,
            class: 0,
        };
        assert_eq!(roundtrip(&m), m);
        let m = Msg::ModelList { models: Vec::new() };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn clean_eof_is_none_and_mid_prefix_truncated() {
        let mut empty: &[u8] = &[];
        assert_eq!(read_frame(&mut empty).unwrap(), None);
        let mut partial: &[u8] = &[0, 0];
        assert_eq!(read_frame(&mut partial), Err(ProtoError::Truncated));
    }

    #[test]
    fn truncated_body_detected() {
        let bytes = Msg::ListModels.encode().unwrap();
        let mut cut = &bytes[..bytes.len() - 1];
        assert_eq!(read_frame(&mut cut), Err(ProtoError::Truncated));
    }

    #[test]
    fn oversized_prefix_rejected_before_allocation() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(MAX_BODY + 1).to_be_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        let mut cursor = &bytes[..];
        assert_eq!(
            read_frame(&mut cursor),
            Err(ProtoError::Oversized(MAX_BODY + 1))
        );
    }

    #[test]
    fn bad_version_rejected() {
        let mut bytes = Msg::ListModels.encode().unwrap();
        bytes[4] = PROTO_VERSION + 1; // first body byte
        let mut cursor = &bytes[..];
        assert_eq!(
            read_frame(&mut cursor),
            Err(ProtoError::BadVersion(PROTO_VERSION + 1))
        );
    }

    #[test]
    fn unknown_kind_and_trailing_bytes_rejected() {
        assert!(matches!(
            Msg::decode(&[PROTO_VERSION, 0x7F]),
            Err(ProtoError::Malformed(_))
        ));
        let mut body = Msg::ListModels.encode().unwrap()[4..].to_vec();
        body.push(0);
        assert!(matches!(Msg::decode(&body), Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn lying_vector_count_rejected_without_allocation() {
        // InferRequest declaring u32::MAX frame values in a tiny body.
        let mut body = vec![PROTO_VERSION, KIND_INFER_REQUEST];
        push_u64(&mut body, 1);
        push_str16(&mut body, "m");
        push_u32(&mut body, u32::MAX);
        assert!(matches!(Msg::decode(&body), Err(ProtoError::Malformed(_))));
    }

    /// The model-list count rides a u16 prefix: 65536 entries used to
    /// wrap to 0 via `as u16` and emit a frame whose declared count
    /// disagrees with its payload. Encode must refuse instead.
    #[test]
    fn model_list_count_overflow_rejected_at_encode() {
        let models = vec![(String::new(), 0u32); u16::MAX as usize + 1];
        let err = Msg::ModelList { models }.encode().unwrap_err();
        assert_eq!(
            err,
            ProtoError::CountOverflow {
                field: "model list",
                count: u16::MAX as usize + 1,
                max: u16::MAX as u64,
            }
        );
        // One fewer entry is the boundary case: exactly u16::MAX entries
        // still fit the prefix (and, at ~393 KB of payload, MAX_BODY).
        let models = vec![(String::new(), 0u32); u16::MAX as usize];
        assert!(Msg::ModelList { models }.encode().is_ok());
    }

    /// A body past [`MAX_BODY`] is refused at encode time (the receiver
    /// would reject the length prefix anyway; the sender must not emit a
    /// frame the protocol forbids).
    #[test]
    fn oversized_body_rejected_at_encode() {
        let frame = vec![0i64; (MAX_BODY as usize / 8) + 1];
        let err = Msg::InferRequest {
            id: 1,
            model: "m".into(),
            frame,
            deadline_us: 0,
            class: 0,
        }
        .encode()
        .unwrap_err();
        assert!(matches!(err, ProtoError::Oversized(n) if n > MAX_BODY));
        // And the largest zoo frame stays comfortably encodable.
        let ok = Msg::InferRequest {
            id: 1,
            model: "vgg_micro".into(),
            frame: vec![0i64; 24 * 24 * 8],
            deadline_us: 0,
            class: 0,
        };
        assert!(ok.encode().is_ok());
    }

    /// `write_frame` refuses the same frames without touching the writer.
    #[test]
    fn write_frame_propagates_encode_refusal_without_writing() {
        let models = vec![(String::new(), 0u32); u16::MAX as usize + 1];
        let mut sink = Vec::new();
        let err = write_frame(&mut sink, &Msg::ModelList { models }).unwrap_err();
        assert!(matches!(err, ProtoError::CountOverflow { .. }));
        assert!(sink.is_empty(), "no bytes may precede an encode refusal");
    }

    #[test]
    fn error_codes_roundtrip_and_classify() {
        for code in [
            ErrorCode::QueueFull,
            ErrorCode::InvalidFrame,
            ErrorCode::UnknownModel,
            ErrorCode::Draining,
            ErrorCode::Malformed,
            ErrorCode::SloMiss,
        ] {
            assert_eq!(ErrorCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(
            ErrorCode::from_reject("slo miss: predicted 900 cycles > budget 600"),
            ErrorCode::SloMiss
        );
        assert_eq!(
            ErrorCode::from_reject("backpressure: all shard queues full"),
            ErrorCode::QueueFull
        );
        assert_eq!(
            ErrorCode::from_reject("no route for model 'x'"),
            ErrorCode::UnknownModel
        );
        assert_eq!(ErrorCode::from_reject("server stopped"), ErrorCode::Draining);
        assert_eq!(
            ErrorCode::from_reject("server dropped request"),
            ErrorCode::Draining
        );
        assert_eq!(
            ErrorCode::from_reject("frame length 3 != expected 64"),
            ErrorCode::InvalidFrame
        );
        // A client-chosen model id embedded in the unknown-route message
        // must not be able to steer classification toward another code.
        assert_eq!(
            ErrorCode::from_reject("no route for model 'backpressure'"),
            ErrorCode::UnknownModel
        );
        assert_eq!(
            ErrorCode::from_reject("no route for model 'server stopped'"),
            ErrorCode::UnknownModel
        );
        assert_eq!(
            ErrorCode::from_reject("no route for model 'slo miss'"),
            ErrorCode::UnknownModel
        );
    }
}
