//! Threaded TCP front-end over the sharded coordinator (DESIGN.md §8).
//!
//! [`NetServer`] fronts a [`Server`] (usually started via
//! `Server::start_multi`) with `std::net` only — no tokio, no external
//! crates, mirroring the crate's dependency-free constraint. The design
//! carries the coordinator's data-rate-aware semantics across the socket
//! boundary instead of flattening them:
//!
//! * **per-connection pipelining** — a connection's reader decodes and
//!   submits requests as fast as they arrive (it never waits for an
//!   answer), while a paired writer thread settles the in-flight
//!   [`Pending`]s and writes responses back **in request order**. A
//!   client may therefore keep many requests outstanding on one socket,
//!   which is exactly what keeps shard micro-batches full;
//! * **backpressure as protocol errors** — when the coordinator refuses a
//!   submission (queue-full spill exhausted, unknown model route, drain),
//!   the reader immediately queues a typed [`Msg::InferErr`] instead of
//!   blocking the socket: the accept loop and other connections never
//!   stall behind one overloaded model;
//! * **graceful drain** — [`NetServer::shutdown`] stops the accept loop,
//!   EOFs every connection's read half (no new requests), flushes the
//!   coordinator via [`Server::drain_shared`] so every in-flight request
//!   is answered, then joins the connection writers — which write those
//!   final responses before the sockets close cleanly. Requests that
//!   race the drain window are answered with [`ErrorCode::Draining`];
//! * **malformed input never panics** — protocol errors are answered
//!   with [`ErrorCode::Malformed`] (request id 0) and the connection is
//!   closed, because a framing violation cannot be resynchronized.
//!
//! Counters live in [`NetMetrics`] (coordinator::metrics), one error
//! tally per [`ErrorCode`], reconciling 1:1 with the coordinator's
//! intake/shard counters — pinned by `tests/net_serving.rs`.

use std::io::{BufWriter, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{NetMetrics, NetMetricsSnapshot, Pending, Server, SubmitOpts};

use super::proto::{self, ErrorCode, Msg};

/// Tunables shared by both network cores (threaded and evented). The
/// defaults are the production values; tests shrink them to drive the
/// stalled-writer teardown path in CI time instead of 30 s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetServerConfig {
    /// Bound on a connection's queued-but-unwritten replies. A client
    /// that pipelines requests without ever reading responses eventually
    /// fills this queue, which blocks its *own* reader (threaded core)
    /// or pauses its read interest (evented core) — per-connection
    /// backpressure instead of unbounded server memory, the net-layer
    /// analogue of the coordinator's bounded shard queues.
    pub writer_queue_depth: usize,
    /// A blocked write to a non-reading client is abandoned after this
    /// long; the connection is then torn down (its coordinator replies
    /// are settled but dropped, never re-queued), so one stalled client
    /// cannot pin a writer thread (or a reactor write buffer) forever.
    pub write_stall_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> NetServerConfig {
        NetServerConfig {
            writer_queue_depth: 1024,
            write_stall_timeout: Duration::from_secs(30),
        }
    }
}

/// One queued reply on a connection's writer channel: either a message
/// that is ready now (typed errors, model lists) or a coordinator
/// [`Pending`] the writer settles in FIFO order — which is what keeps
/// pipelined responses in request order.
enum WriteItem {
    Ready(Msg),
    Wait(u64, Pending),
}

/// The running TCP front-end. Dropping it shuts it down (idempotently).
pub struct NetServer {
    addr: SocketAddr,
    open: Arc<AtomicBool>,
    metrics: Arc<NetMetrics>,
    coordinator: Arc<Server>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<Option<TcpStream>>>>,
    handlers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port) and
    /// start accepting connections for `coordinator`. The advertised
    /// model list is taken from [`Server::model_specs`].
    pub fn bind(addr: &str, coordinator: Arc<Server>) -> Result<NetServer, String> {
        NetServer::bind_with(addr, coordinator, NetServerConfig::default())
    }

    /// [`bind`](NetServer::bind) with explicit tunables — see
    /// [`NetServerConfig`].
    pub fn bind_with(
        addr: &str,
        coordinator: Arc<Server>,
        config: NetServerConfig,
    ) -> Result<NetServer, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let open = Arc::new(AtomicBool::new(true));
        let metrics = Arc::new(NetMetrics::default());
        let conns: Arc<Mutex<Vec<Option<TcpStream>>>> = Arc::new(Mutex::new(Vec::new()));
        let handlers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let specs: Arc<Vec<(String, u32)>> = Arc::new(
            coordinator
                .model_specs()
                .into_iter()
                .map(|(id, len)| (id, len as u32))
                .collect(),
        );

        let accept = {
            let open = Arc::clone(&open);
            let metrics = Arc::clone(&metrics);
            let conns = Arc::clone(&conns);
            let handlers = Arc::clone(&handlers);
            let coordinator = Arc::clone(&coordinator);
            std::thread::Builder::new()
                .name("cnn-flow-net-accept".into())
                .spawn(move || {
                    accept_loop(
                        listener,
                        &open,
                        &metrics,
                        &conns,
                        &handlers,
                        &coordinator,
                        &specs,
                        config,
                    )
                })
                .map_err(|e| format!("spawn accept loop: {e}"))?
        };

        Ok(NetServer {
            addr: local,
            open,
            metrics,
            coordinator,
            accept: Some(accept),
            conns,
            handlers,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time net-layer counters.
    pub fn metrics(&self) -> NetMetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live net counters — lets an out-of-band
    /// observer (the `--metrics-listen` endpoint, the periodic metrics
    /// flush) snapshot mid-run without borrowing the server.
    pub fn metrics_handle(&self) -> Arc<NetMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Graceful drain: stop accepting, EOF every connection's read half,
    /// flush the coordinator ([`Server::drain_shared`] — every accepted
    /// request is answered), join the connection threads (their writers
    /// deliver those final responses), and return the final counters.
    /// Idempotent; also runs on drop.
    ///
    /// The ordering matters: the coordinator drain sits *between* reader
    /// EOF and writer join, because a writer blocked on a long-deadline
    /// micro-batch can only finish once the coordinator's shutdown
    /// markers flush that batch.
    pub fn shutdown(&mut self) -> NetMetricsSnapshot {
        if self.open.swap(false, Ordering::SeqCst) {
            // Unblock the accept loop (it re-checks `open` per accept) by
            // dialing the listener. A wildcard bind (0.0.0.0 / ::) is not
            // self-connectable everywhere, so dial loopback:port instead;
            // if even that fails (firewalled interface), detach the
            // accept thread rather than hang the shutdown on its join.
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match self.addr {
                    SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            match TcpStream::connect_timeout(&wake, Duration::from_secs(1)) {
                Ok(_) => {
                    if let Some(h) = self.accept.take() {
                        let _ = h.join();
                    }
                }
                Err(_) => drop(self.accept.take()),
            }
            for slot in self
                .conns
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .iter()
            {
                if let Some(s) = slot {
                    let _ = s.shutdown(Shutdown::Read);
                }
            }
            self.coordinator.drain_shared();
            let handlers: Vec<_> = std::mem::take(
                &mut *self.handlers.lock().unwrap_or_else(|p| p.into_inner()),
            );
            for h in handlers {
                let _ = h.join();
            }
        }
        self.metrics.snapshot()
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    open: &Arc<AtomicBool>,
    metrics: &Arc<NetMetrics>,
    conns: &Arc<Mutex<Vec<Option<TcpStream>>>>,
    handlers: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    coordinator: &Arc<Server>,
    specs: &Arc<Vec<(String, u32)>>,
    config: NetServerConfig,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if !open.load(Ordering::Acquire) {
                    return;
                }
                // Transient accept error (e.g. EMFILE under a connection
                // flood): back off briefly instead of spinning a core at
                // exactly the moment the host is overloaded.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if !open.load(Ordering::Acquire) {
            // The shutdown wake-up connection (or a client racing it):
            // dropped unanswered — the listener is about to close.
            return;
        }
        let _ = stream.set_nodelay(true);
        // Register the read-half handle BEFORE spawning the handler, so
        // shutdown's conns sweep (which runs after the accept loop joins)
        // is guaranteed to see every live connection — otherwise a reader
        // could block forever and deadlock the handler join.
        let kick = match stream.try_clone() {
            Ok(k) => k,
            Err(_) => continue, // cannot guarantee bounded shutdown: refuse
        };
        metrics.connections.fetch_add(1, Ordering::Relaxed);
        // Reuse a vacated slot so a long-lived server's registry stays
        // proportional to *live* connections, not total ever accepted.
        let slot = {
            let mut conns = conns.lock().unwrap_or_else(|p| p.into_inner());
            match conns.iter().position(|s| s.is_none()) {
                Some(i) => {
                    conns[i] = Some(kick);
                    i
                }
                None => {
                    conns.push(Some(kick));
                    conns.len() - 1
                }
            }
        };
        let hmetrics = Arc::clone(metrics);
        let hconns = Arc::clone(conns);
        let hcoordinator = Arc::clone(coordinator);
        let hspecs = Arc::clone(specs);
        let hopen = Arc::clone(open);
        let spawned = std::thread::Builder::new()
            .name(format!("cnn-flow-net-conn-{slot}"))
            .spawn(move || {
                handle_conn(stream, &hcoordinator, &hspecs, &hopen, &hmetrics, config);
                hconns.lock().unwrap_or_else(|p| p.into_inner())[slot] = None;
            });
        match spawned {
            Ok(handle) => {
                let mut handlers = handlers.lock().unwrap_or_else(|p| p.into_inner());
                // Reap finished connection threads opportunistically so
                // the join list doesn't grow with total connections.
                handlers.retain(|h| !h.is_finished());
                handlers.push(handle);
            }
            Err(_) => {
                // Spawn failed: deregister and drop the connection,
                // balancing the accounting (connections == disconnects
                // must hold for every accepted-then-closed socket).
                conns.lock().unwrap_or_else(|p| p.into_inner())[slot] = None;
                metrics.disconnects.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

fn count_error(metrics: &NetMetrics, code: ErrorCode) {
    let counter = match code {
        ErrorCode::QueueFull => &metrics.err_queue_full,
        ErrorCode::SloMiss => &metrics.err_slo_miss,
        ErrorCode::InvalidFrame => &metrics.err_invalid_frame,
        ErrorCode::UnknownModel => &metrics.err_unknown_model,
        ErrorCode::Draining => &metrics.err_draining,
        ErrorCode::Malformed => &metrics.err_malformed,
    };
    counter.fetch_add(1, Ordering::Relaxed);
}

/// One connection: reader (this thread) + writer (paired thread). The
/// reader never blocks on an answer, the writer preserves request order.
fn handle_conn(
    stream: TcpStream,
    coordinator: &Arc<Server>,
    specs: &Arc<Vec<(String, u32)>>,
    open: &Arc<AtomicBool>,
    metrics: &Arc<NetMetrics>,
    config: NetServerConfig,
) {
    let write_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // A stalled (non-reading) client eventually blocks the writer on a
    // full TCP send buffer; the timeout abandons that write and tears
    // the connection down instead of pinning the thread forever.
    let _ = write_stream.set_write_timeout(Some(config.write_stall_timeout));
    // Bounded: when a client pipelines without reading replies, the
    // reader blocks HERE (its own backpressure) once the writer falls
    // `writer_queue_depth` replies behind — server memory stays bounded.
    let (tx, rx) = mpsc::sync_channel::<WriteItem>(config.writer_queue_depth);
    let writer = {
        let metrics = Arc::clone(metrics);
        std::thread::spawn(move || writer_loop(write_stream, rx, &metrics))
    };
    let mut reader = stream;
    loop {
        match proto::read_frame(&mut reader) {
            Ok(Some(msg)) => {
                if !dispatch(msg, coordinator, specs, open, metrics, &tx) {
                    break;
                }
            }
            Ok(None) => break, // clean EOF (client closed, or drain kick)
            // Transport failures (reset sockets, peers dying mid-frame)
            // are not protocol violations: close quietly, count nothing —
            // `err_malformed` stays a wire-violation counter.
            Err(proto::ProtoError::Io(_)) | Err(proto::ProtoError::Truncated) => break,
            Err(e) => {
                // Framing is lost: answer with a typed error, then close.
                count_error(metrics, ErrorCode::Malformed);
                let _ = tx.send(WriteItem::Ready(Msg::InferErr {
                    id: 0,
                    code: ErrorCode::Malformed,
                    message: e.to_string(),
                }));
                break;
            }
        }
    }
    drop(tx); // writer drains in-flight replies, then exits
    let _ = writer.join();
    let _ = reader.shutdown(Shutdown::Both);
    metrics.disconnects.fetch_add(1, Ordering::Relaxed);
}

/// Handle one decoded message; returns false when the connection must
/// close (protocol violation or a dead writer).
fn dispatch(
    msg: Msg,
    coordinator: &Arc<Server>,
    specs: &Arc<Vec<(String, u32)>>,
    open: &Arc<AtomicBool>,
    metrics: &Arc<NetMetrics>,
    tx: &mpsc::SyncSender<WriteItem>,
) -> bool {
    match msg {
        Msg::InferRequest {
            id,
            model,
            frame,
            deadline_us,
            class,
        } => {
            metrics.requests.fetch_add(1, Ordering::Relaxed);
            let item = if !open.load(Ordering::Acquire) {
                count_error(metrics, ErrorCode::Draining);
                WriteItem::Ready(Msg::InferErr {
                    id,
                    code: ErrorCode::Draining,
                    message: "drain in progress".into(),
                })
            } else {
                let opts = SubmitOpts { deadline_us, class };
                match coordinator.submit_to_opts(&model, frame, opts, None) {
                    Ok(pending) => WriteItem::Wait(id, pending),
                    Err(e) => {
                        let code = ErrorCode::from_reject(&e);
                        count_error(metrics, code);
                        WriteItem::Ready(Msg::InferErr {
                            id,
                            code,
                            message: e,
                        })
                    }
                }
            };
            tx.send(item).is_ok()
        }
        Msg::ListModels => tx
            .send(WriteItem::Ready(Msg::ModelList {
                models: specs.as_ref().clone(),
            }))
            .is_ok(),
        Msg::MetricsText => tx
            .send(WriteItem::Ready(Msg::MetricsTextReply {
                text: coordinator.metrics_text(Some(&metrics.snapshot()), None),
            }))
            .is_ok(),
        // Server→client kinds arriving at the server are a protocol
        // violation; answer once and close.
        Msg::InferOk { .. } | Msg::InferErr { .. } | Msg::ModelList { .. }
        | Msg::MetricsTextReply { .. } => {
            count_error(metrics, ErrorCode::Malformed);
            let _ = tx.send(WriteItem::Ready(Msg::InferErr {
                id: 0,
                code: ErrorCode::Malformed,
                message: "unexpected message kind from client".into(),
            }));
            false
        }
    }
}

/// Settle one queued reply into the wire message it becomes, moving the
/// matching counter (shared verbatim with the evented core's settle
/// path, so both cores count identically).
fn settle_item(item: WriteItem, metrics: &NetMetrics) -> Msg {
    match item {
        WriteItem::Ready(m) => m,
        WriteItem::Wait(id, pending) => match pending.wait() {
            Ok(resp) => {
                // Counted when settled, delivered or not: the
                // counter reconciles with coordinator `completed`.
                metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
                // SLO fields default to 0/false for deadline-free
                // requests, which keeps the reply on the v1 wire
                // (`Msg::wire_version` is content-determined).
                Msg::InferOk {
                    id,
                    argmax: resp.argmax as u32,
                    sim_latency_cycles: resp.sim_latency_cycles,
                    logits: resp.logits,
                    predicted_cycles: resp.predicted_cycles,
                    slo_met: resp.slo_met,
                }
            }
            Err(e) => {
                let code = ErrorCode::from_reject(&e);
                count_error(metrics, code);
                Msg::InferErr {
                    id,
                    code,
                    message: e,
                }
            }
        },
    }
}

fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<WriteItem>, metrics: &NetMetrics) {
    // Once a write fails (client gone), keep *settling* the queued
    // replies — every decoded request must still land in exactly one
    // counter so the documented balance `requests == responses_ok +
    // typed errors` survives clients that pipeline and vanish — but
    // stop touching the dead socket. The reader EOFs right after, so
    // this drain is bounded by the coordinator answering its accepted
    // requests (which it always does, drain included).
    let mut sink_only = false;
    // Batch-flush: frames are *queued* (buffered, unflushed) while more
    // replies are immediately available, and flushed only when the
    // queue goes momentarily empty — a pipelined burst coalesces into
    // few write syscalls instead of one flush per message.
    let mut w = BufWriter::with_capacity(32 * 1024, stream);
    'conn: while let Ok(first) = rx.recv() {
        let mut item = first;
        loop {
            let msg = settle_item(item, metrics);
            if !sink_only && proto::queue_frame(&mut w, &msg).is_err() {
                sink_only = true;
            }
            match rx.try_recv() {
                Ok(next) => item = next,
                Err(mpsc::TryRecvError::Empty) => {
                    if !sink_only && w.flush().is_err() {
                        sink_only = true;
                    }
                    continue 'conn;
                }
                Err(mpsc::TryRecvError::Disconnected) => break 'conn,
            }
        }
    }
    if !sink_only {
        let _ = w.flush();
    }
    let _ = w.get_ref().shutdown(Shutdown::Write);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::net::client::Client;
    use crate::quant::QModel;
    use std::time::Duration;

    fn tiny_server() -> Arc<Server> {
        let qm = QModel::synthetic(8, 4, 6, 0x7CF);
        Arc::new(
            Server::start(
                qm,
                ServerConfig {
                    workers: 1,
                    max_batch: 4,
                    queue_depth: 32,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(0),
                    ..Default::default()
                },
                None,
            )
            .unwrap(),
        )
    }

    #[test]
    fn bind_serve_and_shutdown_roundtrip() {
        let coord = tiny_server();
        let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let client = Client::connect(&net.local_addr().to_string(), 1).unwrap();
        let specs = client.models().unwrap();
        assert_eq!(specs, coord.model_specs());
        let (model, input_len) = specs[0].clone();
        let frame = vec![1i64; input_len];
        let resp = client.infer(&model, &frame).unwrap();
        let direct = coord.infer(frame.clone()).unwrap();
        assert_eq!(resp.logits, direct.logits, "TCP path must be bit-identical");
        assert_eq!(resp.argmax, direct.argmax);
        let snap = net.shutdown();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.responses_ok, 1);
        assert_eq!(snap.connections, 1);
        // Idempotent: a second shutdown returns the same counters.
        assert_eq!(net.shutdown(), snap);
        // The coordinator was drained by the front-end.
        assert_eq!(coord.metrics().completed, 2);
    }

    #[test]
    fn shutdown_refuses_new_connections() {
        let coord = tiny_server();
        let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let addr = net.local_addr().to_string();
        net.shutdown();
        // The listener is gone: connecting either fails outright or the
        // socket is closed before any reply.
        match Client::connect(&addr, 1) {
            Err(_) => {}
            Ok(client) => assert!(client.models().is_err()),
        }
    }
}
