//! Blocking TCP client for the serving front-end, with a small
//! connection pool (DESIGN.md §8).
//!
//! [`Client`] is cheaply cloneable (an `Arc` inside) and thread-safe:
//! every request checks a connection out of the pool (dialing a fresh one
//! when the pool is empty), writes one [`Msg::InferRequest`], and returns
//! a [`ClientPending`] holding that connection. `wait()` reads the reply
//! and returns the connection to the pool — so at most `pool_cap` idle
//! sockets are retained, while the in-flight window is bounded only by
//! the caller (each outstanding [`ClientPending`] owns its own socket;
//! one request is outstanding per connection, the server's per-connection
//! pipelining is exercised by callers that share a raw socket).
//!
//! Server refusals arrive as typed [`ErrorCode`]s inside [`NetError`]
//! (`code: Some(_)`); transport failures (dial, send, read) carry
//! `code: None` and the affected connection is dropped, never pooled.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::proto::{self, ErrorCode, Msg};

/// A client-side inference failure: a typed protocol refusal from the
/// server (`code: Some(..)`, connection still healthy) or a transport
/// error (`code: None`, connection discarded).
#[derive(Debug, Clone)]
pub struct NetError {
    pub code: Option<ErrorCode>,
    pub message: String,
}

impl NetError {
    fn transport(message: String) -> NetError {
        NetError {
            code: None,
            message,
        }
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.code {
            Some(code) => write!(f, "server refused ({code}): {}", self.message),
            None => write!(f, "transport error: {}", self.message),
        }
    }
}

impl std::error::Error for NetError {}

/// One successful answer over the wire — the network image of
/// `coordinator::InferResponse` (service time is a client-side concern,
/// so it is not carried on the wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetResponse {
    /// Final-layer accumulator-scale outputs, lossless i64 — byte-
    /// identical to the in-process response.
    pub logits: Vec<i64>,
    pub argmax: usize,
    pub sim_latency_cycles: u64,
    /// Admission-time predicted completion in modelled cycles (0 for
    /// deadline-free requests — the v1 wire).
    pub predicted_cycles: u64,
    /// Server-side SLO verdict, decided at admission from modelled time
    /// (always false for deadline-free requests).
    pub slo_met: bool,
}

struct ClientInner {
    addr: String,
    pool_cap: usize,
    pool: Mutex<Vec<TcpStream>>,
    next_id: AtomicU64,
}

/// The pooled blocking client. Clone freely; clones share the pool.
#[derive(Clone)]
pub struct Client {
    inner: Arc<ClientInner>,
}

impl Client {
    /// Connect to `addr` (host:port), retaining at most `pool_cap` idle
    /// connections (clamped to ≥ 1). Dials one connection eagerly so an
    /// unreachable server fails here, not on the first request.
    pub fn connect(addr: &str, pool_cap: usize) -> Result<Client, String> {
        let client = Client {
            inner: Arc::new(ClientInner {
                addr: addr.to_string(),
                pool_cap: pool_cap.max(1),
                pool: Mutex::new(Vec::new()),
                next_id: AtomicU64::new(1),
            }),
        };
        let probe = client.dial().map_err(|e| e.message)?;
        client.checkin(probe);
        Ok(client)
    }

    fn dial(&self) -> Result<TcpStream, NetError> {
        let stream = TcpStream::connect(&self.inner.addr)
            .map_err(|e| NetError::transport(format!("connect {}: {e}", self.inner.addr)))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    fn checkout(&self) -> Result<TcpStream, NetError> {
        let pooled = self
            .inner
            .pool
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .pop();
        match pooled {
            Some(stream) => Ok(stream),
            None => self.dial(),
        }
    }

    fn checkin(&self, stream: TcpStream) {
        let mut pool = self.inner.pool.lock().unwrap_or_else(|p| p.into_inner());
        if pool.len() < self.inner.pool_cap {
            pool.push(stream);
        }
        // else: drop — the socket closes, the pool stays small.
    }

    /// Idle pooled connections right now (observability / tests).
    pub fn pooled_idle(&self) -> usize {
        self.inner.pool.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Send one request without waiting for its answer. The returned
    /// [`ClientPending`] owns a connection until settled, so the caller's
    /// outstanding-pending count is its in-flight window.
    pub fn submit(&self, model: &str, frame: &[i64]) -> Result<ClientPending, NetError> {
        self.submit_slo(model, frame, 0, 0)
    }

    /// [`submit`](Client::submit) with the v2 SLO extension: a completion
    /// deadline in microseconds of modelled hardware time (0 = none) and
    /// a priority class. A deadline-free request encodes byte-identically
    /// to the v1 wire, so old servers keep working.
    pub fn submit_slo(
        &self,
        model: &str,
        frame: &[i64],
        deadline_us: u64,
        class: u8,
    ) -> Result<ClientPending, NetError> {
        let mut stream = self.checkout()?;
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let msg = Msg::InferRequest {
            id,
            model: model.to_string(),
            frame: frame.to_vec(),
            deadline_us,
            class,
        };
        proto::write_frame(&mut stream, &msg)
            .map_err(|e| NetError::transport(format!("send request: {e}")))?;
        Ok(ClientPending {
            client: self.clone(),
            stream,
            id,
        })
    }

    /// Blocking inference: submit + wait.
    pub fn infer(&self, model: &str, frame: &[i64]) -> Result<NetResponse, NetError> {
        self.submit(model, frame)?.wait()
    }

    /// Blocking inference with deadline/class: submit_slo + wait.
    pub fn infer_slo(
        &self,
        model: &str,
        frame: &[i64],
        deadline_us: u64,
        class: u8,
    ) -> Result<NetResponse, NetError> {
        self.submit_slo(model, frame, deadline_us, class)?.wait()
    }

    /// Ask the server which models it routes: `(model id, input frame
    /// length)` in route order — enough to synthesize valid traffic.
    pub fn models(&self) -> Result<Vec<(String, usize)>, NetError> {
        let mut stream = self.checkout()?;
        proto::write_frame(&mut stream, &Msg::ListModels)
            .map_err(|e| NetError::transport(format!("send list-models: {e}")))?;
        match proto::read_frame(&mut stream) {
            Ok(Some(Msg::ModelList { models })) => {
                self.checkin(stream);
                Ok(models
                    .into_iter()
                    .map(|(id, len)| (id, len as usize))
                    .collect())
            }
            Ok(Some(other)) => Err(NetError::transport(format!(
                "unexpected reply to list-models: {other:?}"
            ))),
            Ok(None) => Err(NetError::transport(
                "connection closed before model list".into(),
            )),
            Err(e) => Err(NetError::transport(format!("read model list: {e}"))),
        }
    }

    /// Fetch the server's current metrics as a Prometheus text page
    /// (DESIGN.md §13) — a fresh snapshot rendered at dispatch time.
    pub fn metrics_text(&self) -> Result<String, NetError> {
        let mut stream = self.checkout()?;
        proto::write_frame(&mut stream, &Msg::MetricsText)
            .map_err(|e| NetError::transport(format!("send metrics-text: {e}")))?;
        match proto::read_frame(&mut stream) {
            Ok(Some(Msg::MetricsTextReply { text })) => {
                self.checkin(stream);
                Ok(text)
            }
            Ok(Some(other)) => Err(NetError::transport(format!(
                "unexpected reply to metrics-text: {other:?}"
            ))),
            Ok(None) => Err(NetError::transport(
                "connection closed before metrics text".into(),
            )),
            Err(e) => Err(NetError::transport(format!("read metrics text: {e}"))),
        }
    }
}

/// A submitted-but-unanswered network request; holds its connection.
pub struct ClientPending {
    client: Client,
    stream: TcpStream,
    id: u64,
}

impl ClientPending {
    /// Block until the reply arrives. Typed server refusals return the
    /// connection to the pool (the stream is still in sync); transport
    /// errors and id mismatches discard it.
    pub fn wait(self) -> Result<NetResponse, NetError> {
        let ClientPending {
            client,
            mut stream,
            id,
        } = self;
        match proto::read_frame(&mut stream) {
            Ok(Some(Msg::InferOk {
                id: got,
                argmax,
                sim_latency_cycles,
                logits,
                predicted_cycles,
                slo_met,
            })) if got == id => {
                client.checkin(stream);
                Ok(NetResponse {
                    logits,
                    argmax: argmax as usize,
                    sim_latency_cycles,
                    predicted_cycles,
                    slo_met,
                })
            }
            Ok(Some(Msg::InferErr {
                id: got,
                code,
                message,
            })) if got == id || got == 0 => {
                // id 0 marks a connection-level error (e.g. malformed):
                // the stream cannot be reused after those.
                if got == id && code != ErrorCode::Malformed {
                    client.checkin(stream);
                }
                Err(NetError {
                    code: Some(code),
                    message,
                })
            }
            Ok(Some(other)) => Err(NetError::transport(format!(
                "reply desynchronized (expected id {id}): {other:?}"
            ))),
            Ok(None) => Err(NetError::transport(
                "connection closed before reply".into(),
            )),
            Err(e) => Err(NetError::transport(format!("read reply: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Server, ServerConfig};
    use crate::net::server::NetServer;
    use crate::quant::QModel;
    use std::time::Duration;

    fn serving_pair() -> (Arc<Server>, NetServer) {
        let qm = QModel::synthetic(8, 4, 6, 0xC11);
        let coord = Arc::new(
            Server::start(
                qm,
                ServerConfig {
                    workers: 1,
                    max_batch: 4,
                    queue_depth: 32,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(0),
                    ..Default::default()
                },
                None,
            )
            .unwrap(),
        );
        let net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        (coord, net)
    }

    #[test]
    fn pool_reuses_connections_up_to_cap() {
        let (_coord, mut net) = serving_pair();
        let client = Client::connect(&net.local_addr().to_string(), 2).unwrap();
        let (model, len) = client.models().unwrap()[0].clone();
        let frame = vec![2i64; len];
        for _ in 0..4 {
            client.infer(&model, &frame).unwrap();
        }
        // Serial requests reuse the single pooled connection.
        assert_eq!(client.pooled_idle(), 1);
        let snap = net.shutdown();
        assert_eq!(snap.connections, 1, "pooled connection must be reused");
        assert_eq!(snap.responses_ok, 4);
    }

    #[test]
    fn concurrent_pendings_each_own_a_connection() {
        let (_coord, mut net) = serving_pair();
        let client = Client::connect(&net.local_addr().to_string(), 3).unwrap();
        let (model, len) = client.models().unwrap()[0].clone();
        let frame = vec![1i64; len];
        let pendings: Vec<ClientPending> = (0..3)
            .map(|_| client.submit(&model, &frame).unwrap())
            .collect();
        for p in pendings {
            p.wait().unwrap();
        }
        assert_eq!(client.pooled_idle(), 3);
        let snap = net.shutdown();
        assert_eq!(snap.responses_ok, 3);
        assert!(snap.connections >= 2, "parallel pendings need own sockets");
    }

    #[test]
    fn connect_to_dead_port_fails_eagerly() {
        // Bind-then-drop leaves a port that refuses connections.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(Client::connect(&addr, 1).is_err());
    }
}
