//! TCP serving front-end (system S11): the network ingress for the
//! sharded coordinator — `std::net` only, zero external dependencies.
//!
//! PRs 1–4 built a multi-model, micro-batching coordinator whose
//! continuous-flow engine keeps the modelled hardware near 100%
//! utilisation; this layer carries those semantics across a socket
//! instead of flattening them (the batched-RPC front-end lesson of
//! Clipper-style prediction serving — see PAPERS.md): backpressure and
//! drain surface as **typed protocol errors**, never as a blocked accept
//! loop, and per-connection pipelining keeps shard micro-batches full.
//!
//! Three pieces (contracts in DESIGN.md §8, pinned by
//! `tests/net_serving.rs`):
//!
//! * [`proto`] — the versioned, length-prefixed binary wire protocol:
//!   model-tagged requests, lossless i64 logits, and one
//!   [`proto::ErrorCode`] per coordinator rejection reason;
//! * [`server`] — the threaded front-end over
//!   [`crate::coordinator::Server`]: reader/writer pair per connection
//!   (pipelined, in-order responses), graceful drain, malformed input
//!   answered rather than panicking;
//! * [`client`] — the blocking client with a small connection pool,
//!   whose responses are **byte-identical** to in-process serving
//!   (`coordinator::loadgen::replay_net` replays a seeded `MultiTrace`
//!   over localhost to pin exactly that).

pub mod client;
pub mod proto;
pub mod server;

pub use client::{Client, ClientPending, NetError, NetResponse};
pub use proto::{ErrorCode, Msg, ProtoError, MAX_BODY, PROTO_VERSION};
pub use server::NetServer;
