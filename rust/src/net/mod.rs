//! TCP serving front-end (system S11): the network ingress for the
//! sharded coordinator — `std::net` only, zero external dependencies.
//!
//! PRs 1–4 built a multi-model, micro-batching coordinator whose
//! continuous-flow engine keeps the modelled hardware near 100%
//! utilisation; this layer carries those semantics across a socket
//! instead of flattening them (the batched-RPC front-end lesson of
//! Clipper-style prediction serving — see PAPERS.md): backpressure and
//! drain surface as **typed protocol errors**, never as a blocked accept
//! loop, and per-connection pipelining keeps shard micro-batches full.
//!
//! Pieces (contracts in DESIGN.md §8 and §10, pinned by
//! `tests/net_serving.rs` and `tests/net_evented.rs`):
//!
//! * [`proto`] — the versioned, length-prefixed binary wire protocol:
//!   model-tagged requests, lossless i64 logits, one
//!   [`proto::ErrorCode`] per coordinator rejection reason, and the
//!   incremental [`proto::FrameDecoder`] used by the evented paths;
//! * [`server`] — the threaded front-end over
//!   [`crate::coordinator::Server`]: reader/writer pair per connection
//!   (pipelined, in-order responses), graceful drain, malformed input
//!   answered rather than panicking;
//! * [`reactor`] — the std-only readiness poller (epoll on Linux,
//!   `poll(2)` elsewhere on unix) plus a socketpair-based waker;
//! * [`evented`] — the nonblocking single-threaded front-end built on
//!   the reactor: O(1) threads for 10k+ connections, with the threaded
//!   [`server::NetServer`] kept as its differential oracle;
//! * [`client`] — the blocking client with a small connection pool,
//!   whose responses are **byte-identical** to in-process serving
//!   (`coordinator::loadgen::replay_net` replays a seeded `MultiTrace`
//!   over localhost to pin exactly that);
//! * [`fanin`] — the poller-multiplexed load generator measuring
//!   connections-vs-throughput and RTT under fan-in.

pub mod client;
#[cfg(unix)]
pub mod evented;
#[cfg(unix)]
pub mod fanin;
pub mod proto;
#[cfg(unix)]
pub mod reactor;
pub mod server;

pub use client::{Client, ClientPending, NetError, NetResponse};
#[cfg(unix)]
pub use evented::EventedServer;
pub use proto::{ErrorCode, FrameDecoder, Msg, ProtoError, MAX_BODY, PROTO_VERSION};
pub use server::{NetServer, NetServerConfig};

use std::sync::Arc;

use crate::coordinator::{NetMetrics, NetMetricsSnapshot, ReactorStats, ReactorStatsSnapshot, Server};

/// Which network core serves the socket: the threaded oracle or the
/// evented reactor. Mirrors `coordinator::EngineKind`'s selection
/// pattern — a CLI flag (`--net-core`) plus an env override
/// (`$CNN_FLOW_NET`) that CI's matrix legs use to force every
/// default-configured network test through the evented core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetCore {
    /// Thread-per-connection [`NetServer`] — the differential oracle.
    #[default]
    Threaded,
    /// Single-threaded nonblocking reactor ([`EventedServer`]).
    Evented,
}

impl NetCore {
    /// Parse a core name (`threaded` | `evented`; case-insensitive) —
    /// shared by the env override and the CLI's `--net-core` flag.
    pub fn parse(s: &str) -> Option<NetCore> {
        match s.to_ascii_lowercase().as_str() {
            "threaded" => Some(NetCore::Threaded),
            "evented" | "reactor" => Some(NetCore::Evented),
            _ => None,
        }
    }

    /// The core named by `$CNN_FLOW_NET`. Unset or empty means "no
    /// override"; an unrecognized non-empty value **panics** — silently
    /// falling back to the threaded default would turn a typo in the CI
    /// matrix into a leg that tests the wrong core while staying green.
    pub fn from_env() -> Option<NetCore> {
        let raw = std::env::var("CNN_FLOW_NET").ok()?;
        if raw.is_empty() {
            return None;
        }
        match Self::parse(&raw) {
            Some(core) => Some(core),
            None => panic!(
                "CNN_FLOW_NET='{raw}' is not a recognized network core \
                 (expected threaded | evented)"
            ),
        }
    }

    /// [`NetCore::from_env`], falling back to the threaded default.
    pub fn default_from_env() -> NetCore {
        Self::from_env().unwrap_or_default()
    }
}

impl std::fmt::Display for NetCore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            NetCore::Threaded => "threaded",
            NetCore::Evented => "evented",
        })
    }
}

/// A running front-end of either core behind one API, so callers (the
/// CLI, the differential tests, the bench harness) select a core with
/// a value instead of a code path.
pub enum FrontEnd {
    Threaded(NetServer),
    #[cfg(unix)]
    Evented(EventedServer),
}

impl FrontEnd {
    /// Bind `addr` and serve `coordinator` on the chosen core.
    pub fn bind(core: NetCore, addr: &str, coordinator: Arc<Server>) -> Result<FrontEnd, String> {
        FrontEnd::bind_with(core, addr, coordinator, NetServerConfig::default())
    }

    /// [`bind`](FrontEnd::bind) with explicit tunables.
    pub fn bind_with(
        core: NetCore,
        addr: &str,
        coordinator: Arc<Server>,
        config: NetServerConfig,
    ) -> Result<FrontEnd, String> {
        match core {
            NetCore::Threaded => {
                NetServer::bind_with(addr, coordinator, config).map(FrontEnd::Threaded)
            }
            #[cfg(unix)]
            NetCore::Evented => {
                EventedServer::bind_with(addr, coordinator, config).map(FrontEnd::Evented)
            }
            #[cfg(not(unix))]
            NetCore::Evented => Err("the evented network core requires a unix platform".into()),
        }
    }

    pub fn core(&self) -> NetCore {
        match self {
            FrontEnd::Threaded(_) => NetCore::Threaded,
            #[cfg(unix)]
            FrontEnd::Evented(_) => NetCore::Evented,
        }
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        match self {
            FrontEnd::Threaded(s) => s.local_addr(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.local_addr(),
        }
    }

    pub fn metrics(&self) -> NetMetricsSnapshot {
        match self {
            FrontEnd::Threaded(s) => s.metrics(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.metrics(),
        }
    }

    /// Shared handle to the live net counters, for out-of-band
    /// observers (metrics endpoint, periodic flush) that outlive this
    /// borrow.
    pub fn metrics_handle(&self) -> Arc<NetMetrics> {
        match self {
            FrontEnd::Threaded(s) => s.metrics_handle(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.metrics_handle(),
        }
    }

    /// Shared handle to the live reactor counters — `None` on the
    /// threaded core.
    pub fn reactor_handle(&self) -> Option<Arc<ReactorStats>> {
        match self {
            FrontEnd::Threaded(_) => None,
            #[cfg(unix)]
            FrontEnd::Evented(s) => Some(s.reactor_handle()),
        }
    }

    /// Readiness-loop counters — `None` on the threaded core.
    pub fn reactor_stats(&self) -> Option<ReactorStatsSnapshot> {
        match self {
            FrontEnd::Threaded(_) => None,
            #[cfg(unix)]
            FrontEnd::Evented(s) => Some(s.reactor_stats()),
        }
    }

    /// Graceful drain (same ordering contract on both cores); returns
    /// the final metrics snapshot.
    pub fn shutdown(&mut self) -> NetMetricsSnapshot {
        match self {
            FrontEnd::Threaded(s) => s.shutdown(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.shutdown(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_core_parse_and_display() {
        assert_eq!(NetCore::parse("threaded"), Some(NetCore::Threaded));
        assert_eq!(NetCore::parse("Evented"), Some(NetCore::Evented));
        assert_eq!(NetCore::parse("reactor"), Some(NetCore::Evented));
        assert_eq!(NetCore::parse("epoll"), None);
        assert_eq!(NetCore::Threaded.to_string(), "threaded");
        assert_eq!(NetCore::Evented.to_string(), "evented");
        assert_eq!(NetCore::default(), NetCore::Threaded);
    }
}
