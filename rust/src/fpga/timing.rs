//! Analytic latency/throughput model for designs we cannot run through
//! the value-level pipeline simulator (no trained weights, e.g. MobileNet
//! in Table IX). For models with artifacts, prefer
//! [`crate::sim::pipeline::PipelineSim`]'s measured cycles.

use crate::flow::RateAnalysis;
use crate::model::LayerKind;

/// Cycle-level timing of a continuous-flow design.
#[derive(Debug, Clone, Copy)]
pub struct TimingEstimate {
    /// Steady-state cycles per input frame (the input stream length plus
    /// the padding zero-feed of the first layer).
    pub cycles_per_frame: f64,
    /// Input-to-last-output latency of one frame in cycles.
    pub latency_cycles: f64,
}

/// Analytic timing from the rate analysis alone.
///
/// * throughput: the input is the bottleneck of a continuous-flow design —
///   one frame takes `f0^2 * d0 / r0` cycles (+ the Section III-B
///   inter-frame zero rows when the first layer pads);
/// * latency: each sliding-window layer must fill `k` input rows before
///   its first output, each dense layer must absorb all inputs plus its
///   weight cycle; fills are expressed at each layer's own input rate.
pub fn timing_analytic(analysis: &RateAnalysis, first_layer_pad: usize) -> TimingEstimate {
    let first = match analysis.layers.first() {
        Some(f) => f,
        None => {
            return TimingEstimate {
                cycles_per_frame: 0.0,
                latency_cycles: 0.0,
            }
        }
    };
    let f0 = first.shaped.input.f as f64;
    let d0 = first.shaped.input.d as f64;
    let r0 = analysis.r0.to_f64();
    let gap = if first_layer_pad > 0 {
        (first_layer_pad as f64) * (f0 + 1.0)
    } else {
        0.0
    };
    let cycles_per_frame = (f0 * f0 + gap) * d0 / r0;

    let mut latency = 0.0;
    for l in &analysis.layers {
        let r_in = l.r_in.to_f64();
        let d_in = l.d_in() as f64;
        let fill = match l.shaped.layer.kind {
            LayerKind::Dense => {
                // All inputs + one weight-cycle tail (h) + pipeline regs.
                d_in / r_in + 4.0
            }
            _ => {
                let f_in = l.shaped.input.f as f64;
                let k = l.shaped.layer.k as f64;
                // k input rows must arrive before the first output row.
                k * f_in * (l.shaped.input.d as f64) / r_in + 4.0
            }
        };
        latency += fill;
    }
    TimingEstimate {
        cycles_per_frame,
        latency_cycles: latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{analyze, Ratio};
    use crate::model::zoo;

    #[test]
    fn mobilenet_throughput_matches_paper_fps() {
        // Paper Table IX: ours reaches 6,944 FPS at 350 MHz on 224x224x3
        // at full input rate -> cycles/frame = 350e6 / 6944 ~= 50,400.
        let a = analyze(&zoo::mobilenet_v1(100), None).unwrap();
        let t = timing_analytic(&a, 1);
        let fps_at_350 = 350.0e6 / t.cycles_per_frame;
        assert!(
            (6_500.0..7_100.0).contains(&fps_at_350),
            "fps {fps_at_350} (cycles/frame {})",
            t.cycles_per_frame
        );
    }

    #[test]
    fn jsc_throughput_scales_with_rate() {
        // JSC MLP: 16 features at r0 -> 16/r0 cycles per inference.
        for (r0, expect) in [
            (Ratio::int(16), 1.0),
            (Ratio::int(1), 16.0),
            (Ratio::new(1, 16), 256.0),
        ] {
            let a = analyze(&zoo::jsc_mlp(), Some(r0)).unwrap();
            let t = timing_analytic(&a, 0);
            assert!(
                (t.cycles_per_frame - expect).abs() < 1e-9,
                "r0={r0}: {} != {expect}",
                t.cycles_per_frame
            );
        }
    }

    #[test]
    fn latency_grows_as_rate_falls() {
        let mut prev = 0.0;
        for r0 in [Ratio::int(16), Ratio::int(4), Ratio::int(1), Ratio::new(1, 4)] {
            let a = analyze(&zoo::jsc_mlp(), Some(r0)).unwrap();
            let t = timing_analytic(&a, 0);
            assert!(t.latency_cycles > prev, "r0={r0}");
            prev = t.latency_cycles;
        }
    }

    #[test]
    fn latency_exceeds_single_frame_time_for_deep_models() {
        let a = analyze(&zoo::mobilenet_v1(100), None).unwrap();
        let t = timing_analytic(&a, 1);
        assert!(t.latency_cycles > t.cycles_per_frame);
    }
}
