//! Resource -> LUT/FF/DSP/BRAM/Fmax/power mapping.
//!
//! Per-primitive costs (8-bit datapath on UltraScale+), calibrated once
//! against the paper's published JSC and MobileNet rows and then held
//! fixed — the comparison is pinned by this module's unit tests:
//!
//! * adder (8b + carry headroom): 8 LUTs;
//! * interleave/data mux: folded into unit control (weight muxes are ROM);
//! * per-unit control (counters, pad selects): 15 LUTs;
//! * DSP48E2: two 8x8 multiplications per DSP;
//! * no-DSP multiplier (FloPoCo-style constant/KCM mult): 16 LUTs;
//! * FF: 9 per architectural register + mult pipeline (32/DSP-mult,
//!   40/LUT-mult);
//! * weight ROMs: 8 bits/word into BRAM18 pools per layer once C > 1.

use crate::complexity::{layer_cost, CostOpts, Resources};
use crate::flow::{PlannedLayer, UnitPlan};
use crate::quant::QModel;

/// Estimator options.
#[derive(Debug, Clone, Copy)]
pub struct EstimatorOpts {
    /// Map multiplications onto DSP blocks (false = LUT multipliers).
    pub use_dsp: bool,
    /// Fraction of multiplier lanes that are multiplierless ({0, ±2^n}
    /// weights). `None` = measure from a QModel, or use the QAT-typical
    /// default 0.30 when no weights are available.
    pub trivial_frac: Option<f64>,
}

impl Default for EstimatorOpts {
    fn default() -> Self {
        Self {
            use_dsp: true,
            trivial_frac: None,
        }
    }
}

/// The estimate for one design point.
#[derive(Debug, Clone, Copy)]
pub struct FpgaEstimate {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    /// BRAM36 equivalents (halves = BRAM18), like the paper's tables.
    pub bram36: f64,
    pub fmax_mhz: f64,
    /// Dynamic + static power at fmax, watts.
    pub power_w: f64,
}

const LUT_PER_ADDER: u64 = 8;
const LUT_PER_UNIT_CTRL: u64 = 15;
const LUT_PER_LUTMULT: u64 = 16;
const FF_PER_REG: u64 = 9;
const FF_PER_DSPMULT: u64 = 32;
const FF_PER_LUTMULT: u64 = 40;
const MULTS_PER_DSP: u64 = 2;
const BRAM18_BITS: u64 = 18 * 1024;

/// Is an int8 weight trivially implementable (0 or ±2^n)?
pub fn weight_is_trivial(w: i64) -> bool {
    let a = w.unsigned_abs();
    a == 0 || a.is_power_of_two()
}

/// Measured fraction of *lanes* that are multiplierless, given the real
/// quantized weights: a lane cycling through C configurations is trivial
/// only if all its C weights are trivial.
pub fn measured_trivial_frac(qm: &QModel, plans: &[PlannedLayer]) -> f64 {
    let mut lanes = 0f64;
    let mut trivial = 0f64;
    for (ql, pl) in qm.layers.iter().zip(plans.iter()) {
        if ql.w_q.is_empty() {
            continue;
        }
        let c = pl.plan.configs().max(1);
        // Partition the weight list into lanes of C consecutive configs.
        // (The exact ROM order doesn't change the count materially; what
        // matters is the all-C-trivial requirement.)
        for chunk in ql.w_q.chunks(c) {
            lanes += 1.0;
            if chunk.iter().all(|&w| weight_is_trivial(w)) {
                trivial += 1.0;
            }
        }
    }
    if lanes == 0.0 {
        0.0
    } else {
        trivial / lanes
    }
}

/// Estimate one design from its total abstract resources.
///
/// `max_configs` is the largest per-unit configuration count in the design
/// (drives the ROM->BRAM decision); `layers_with_rom` pools ROM bits.
pub fn estimate_resources(
    total: &Resources,
    per_layer: &[(Resources, usize)], // (cost, configs) per layer
    opts: EstimatorOpts,
) -> FpgaEstimate {
    let trivial = opts.trivial_frac.unwrap_or(0.30).clamp(0.0, 1.0);
    let mults_effective =
        ((total.multipliers as f64) * (1.0 - trivial)).ceil() as u64;

    let units = total.kpus + total.fcus + total.ppus;
    let mut lut = total.adders * LUT_PER_ADDER
        + units * LUT_PER_UNIT_CTRL
        + total.max_units * LUT_PER_ADDER; // a MAX unit ~ an 8b comparator
    let dsp;
    let ff_mult;
    if opts.use_dsp {
        dsp = mults_effective.div_ceil(MULTS_PER_DSP);
        ff_mult = total.multipliers * FF_PER_DSPMULT;
    } else {
        dsp = 0;
        lut += mults_effective * LUT_PER_LUTMULT;
        ff_mult = total.multipliers * FF_PER_LUTMULT;
    }
    // Registers: single-config chains are plain FFs; the depth-C FIFOs of
    // interleaved units map onto SRL shift registers (1 LUT per 32 bits
    // of depth, 8-bit words -> words/4 LUTs).
    let mut ff_regs = 0u64;
    for (cost, configs) in per_layer {
        if *configs > 1 {
            lut += cost.registers.div_ceil(4);
            // One output FF per SRL chain word-slice (pipelining).
            ff_regs += (cost.registers / (*configs as u64)).max(1) * FF_PER_REG;
        } else {
            ff_regs += cost.registers * FF_PER_REG;
        }
    }
    let ff = ff_regs + ff_mult + units * 8;

    // Weight ROMs: per layer, pooled into BRAM18s when the layer
    // reconfigures (C > 1); single-config weights are constants in logic.
    let mut bram18 = 0u64;
    for (cost, configs) in per_layer {
        if *configs > 1 && cost.rom_words > 0 {
            bram18 += (cost.rom_words * 8).div_ceil(BRAM18_BITS).max(1);
        }
    }
    let bram36 = bram18 as f64 / 2.0;

    // Fmax model (calibrated against the paper's published rows): fully
    // combinational single-config designs close near 690 MHz; BRAM-backed
    // reconfigurable designs near 600 MHz; very large designs derate with
    // size (routing pressure).
    let base = if bram18 == 0 { 690.0 } else { 600.0 };
    let size_derate =
        1.0 / (1.0 + lut as f64 / 500_000.0 + dsp as f64 / 20_000.0 + ff as f64 / 2_000_000.0);
    let fmax_mhz = base * size_derate;

    // Power model: static + activity-weighted dynamic at fmax.
    let dyn_w = fmax_mhz
        * (lut as f64 * 0.08 + ff as f64 * 0.02 + dsp as f64 * 4.0 + bram36 * 3.0)
        / 1.0e6;
    let power_w = 2.5 + dyn_w;

    FpgaEstimate {
        lut,
        ff,
        dsp,
        bram36,
        fmax_mhz,
        power_w,
    }
}

/// Estimate a whole planned model. When `qmodel` is given, the trivial
/// multiplier fraction is measured from the real quantized weights.
pub fn estimate_model(
    plans: &[PlannedLayer],
    opts: EstimatorOpts,
    qmodel: Option<&QModel>,
) -> FpgaEstimate {
    let per_layer: Vec<(Resources, usize)> = plans
        .iter()
        .map(|p| (layer_cost(p, CostOpts::FULL), p.plan.configs()))
        .collect();
    let total = Resources::sum(per_layer.iter().map(|(r, _)| r));
    let mut opts = opts;
    if opts.trivial_frac.is_none() {
        if let Some(qm) = qmodel {
            opts.trivial_frac = Some(measured_trivial_frac(qm, plans));
        }
    }
    estimate_resources(&total, &per_layer, opts)
}

/// Sum of FCU/KPU/PPU counts (for reports).
pub fn unit_count(plans: &[PlannedLayer]) -> u64 {
    plans.iter().map(|p| p.plan.unit_count() as u64).sum()
}

/// Largest configuration count in the plan.
pub fn max_configs(plans: &[PlannedLayer]) -> usize {
    plans.iter().map(|p| p.plan.configs()).max().unwrap_or(1)
}

/// Helper for reports: is any layer stalled?
pub fn any_stalled(plans: &[PlannedLayer]) -> bool {
    plans.iter().any(|p| p.plan.stalled())
}

/// Reconstruct a rough unit plan summary string ("37 FCUs, C<=16").
pub fn plan_summary(plans: &[PlannedLayer]) -> String {
    let mut kpus = 0usize;
    let mut fcus = 0usize;
    let mut ppus = 0usize;
    for p in plans {
        match p.plan {
            UnitPlan::Kpu { kpus: k, .. } => kpus += k,
            UnitPlan::Fcu { fcus: f, .. } => fcus += f,
            UnitPlan::Ppu { ppus: p2, .. } => ppus += p2,
        }
    }
    format!(
        "{kpus} KPUs, {fcus} FCUs, {ppus} PPUs, C_max={}",
        max_configs(plans)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{analyze, plan_all, Ratio};
    use crate::model::zoo;

    fn jsc_plans(r0: Ratio) -> Vec<PlannedLayer> {
        plan_all(&analyze(&zoo::jsc_mlp(), Some(r0)).unwrap())
    }

    #[test]
    fn trivial_weights() {
        for w in [0i64, 1, -1, 2, -4, 64, -128] {
            assert!(weight_is_trivial(w), "{w}");
        }
        for w in [3i64, -5, 7, 100, -127] {
            assert!(!weight_is_trivial(w), "{w}");
        }
    }

    #[test]
    fn jsc_full_rate_lut_in_paper_band() {
        // Paper Table X, Proposed (DSP) r0=16: 5,308 LUT, 184 DSP.
        let plans = jsc_plans(Ratio::int(16));
        let est = estimate_model(
            &plans,
            EstimatorOpts {
                use_dsp: true,
                trivial_frac: Some(0.30),
            },
            None,
        );
        assert!(
            (4_000..8_000).contains(&est.lut),
            "LUT {} outside paper band",
            est.lut
        );
        assert!(
            (150..320).contains(&est.dsp),
            "DSP {} outside paper band",
            est.dsp
        );
        assert!(est.bram36 == 0.0, "full rate uses no BRAM");
        assert!(est.fmax_mhz > 600.0);
    }

    #[test]
    fn jsc_resources_shrink_with_rate() {
        let mut prev_lut = u64::MAX;
        let mut prev_dsp = u64::MAX;
        for r0 in [
            Ratio::int(16),
            Ratio::int(8),
            Ratio::int(4),
            Ratio::int(2),
            Ratio::int(1),
            Ratio::new(1, 2),
            Ratio::new(1, 4),
        ] {
            let est = estimate_model(
                &jsc_plans(r0),
                EstimatorOpts {
                    use_dsp: true,
                    trivial_frac: Some(0.30),
                },
                None,
            );
            assert!(est.lut <= prev_lut, "LUT not shrinking at r0={r0}");
            assert!(est.dsp <= prev_dsp, "DSP not shrinking at r0={r0}");
            prev_lut = est.lut;
            prev_dsp = est.dsp;
        }
    }

    #[test]
    fn no_dsp_variant_trades_dsp_for_lut() {
        let plans = jsc_plans(Ratio::int(16));
        let dsp = estimate_model(
            &plans,
            EstimatorOpts {
                use_dsp: true,
                trivial_frac: Some(0.3),
            },
            None,
        );
        let nodsp = estimate_model(
            &plans,
            EstimatorOpts {
                use_dsp: false,
                trivial_frac: Some(0.3),
            },
            None,
        );
        assert_eq!(nodsp.dsp, 0);
        assert!(nodsp.lut > dsp.lut);
    }

    #[test]
    fn mobilenet_fits_on_xcvu37p() {
        // Paper: MobileNetV1 (ours) fits a single XCVU37P at ~205k LUT,
        // ~5.7k DSP, ~350 MHz.
        let plans = plan_all(&analyze(&zoo::mobilenet_v1(100), None).unwrap());
        let est = estimate_model(&plans, EstimatorOpts::default(), None);
        let d = crate::fpga::XCVU37P;
        assert!(
            d.fits(est.lut, est.ff, est.dsp, est.bram36),
            "doesn't fit: {est:?}"
        );
        assert!(
            (200.0..500.0).contains(&est.fmax_mhz),
            "fmax {}",
            est.fmax_mhz
        );
        assert!(est.power_w < 45.0, "power {}", est.power_w);
    }

    #[test]
    fn measured_trivial_frac_from_artifact() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights/jsc.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let qm = QModel::load(&path).unwrap();
        let model = crate::sim::pipeline::qmodel_to_model(&qm);
        let plans = plan_all(&analyze(&model, None).unwrap());
        let frac = measured_trivial_frac(&qm, &plans);
        assert!((0.0..=1.0).contains(&frac));
        // Fully-parallel lanes (C=1) over int8 QAT weights: a nonzero but
        // minority fraction is trivial.
        assert!(frac > 0.01 && frac < 0.9, "frac {frac}");
    }
}
