//! FPGA device database: the parts used in the paper's evaluation.

/// Capacity of one FPGA part.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub bram36: u64,
    /// Practical clock-tree ceiling (the paper caps analysis at 800 MHz).
    pub fmax_cap_mhz: f64,
}

/// Xilinx Virtex UltraScale+ XCVU37P (the paper's MobileNet target and
/// the [18] comparison part).
pub const XCVU37P: Device = Device {
    name: "XCVU37P",
    luts: 1_303_680,
    ffs: 2_607_360,
    dsps: 9_024,
    bram36: 2_016,
    fmax_cap_mhz: 800.0,
};

/// Xilinx Virtex UltraScale+ XCVU9P (the JSC / Table X part).
pub const XCVU9P: Device = Device {
    name: "xcvu9p-flgb2104-2-i",
    luts: 1_182_240,
    ffs: 2_364_480,
    dsps: 6_840,
    bram36: 2_160,
    fmax_cap_mhz: 800.0,
};

/// AMD Alveo U280 (the FINN comparison row of Table IX).
pub const ALVEO_U280: Device = Device {
    name: "Alveo U280",
    luts: 1_304_000,
    ffs: 2_607_000,
    dsps: 9_024,
    bram36: 2_016,
    fmax_cap_mhz: 800.0,
};

impl Device {
    /// Does an estimate fit on this part?
    pub fn fits(&self, lut: u64, ff: u64, dsp: u64, bram36: f64) -> bool {
        lut <= self.luts && ff <= self.ffs && dsp <= self.dsps && bram36 <= self.bram36 as f64
    }

    /// LUT utilisation fraction.
    pub fn lut_util(&self, lut: u64) -> f64 {
        lut as f64 / self.luts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn devices_have_positive_capacity() {
        for d in [XCVU37P, XCVU9P, ALVEO_U280] {
            assert!(d.luts > 1_000_000);
            assert!(d.dsps > 1_000);
            assert!(d.fmax_cap_mhz > 0.0);
        }
    }

    #[test]
    fn fits_checks_all_dimensions() {
        let d = XCVU9P;
        assert!(d.fits(1000, 1000, 10, 1.5));
        assert!(!d.fits(d.luts + 1, 0, 0, 0.0));
        assert!(!d.fits(0, 0, d.dsps + 1, 0.0));
    }
}
