//! FPGA synthesis estimator (system S8) — the documented substitution for
//! the paper's Vivado flow (DESIGN.md §2).
//!
//! Maps the abstract resource counts of [`crate::complexity`] to
//! LUT / FF / DSP / BRAM on Xilinx UltraScale+ parts, and models Fmax,
//! latency, throughput, power and energy so Tables IX/X and Fig. 13 can be
//! regenerated. Absolute numbers are estimates; the *shape* (scaling with
//! the data rate r0, DSP vs no-DSP trade-off, Pareto frontier position) is
//! the reproduction target — the calibration notes live in [`estimate`],
//! with the measured-vs-paper comparison pinned by its unit tests.
//!
//! Key mapping decisions (each mirrors a statement in the paper):
//!
//! * weight multiplexers are ROMs: "almost all multiplexers can be
//!   implemented using BRAM" — so Eq.-28/35 muxes cost BRAM bits, not LUTs;
//! * one DSP48E2 implements two 8-bit multiplications (the [18] trick);
//! * multiplier lanes whose weight set is entirely {0, ±2^n} are
//!   multiplierless (shift/wire) — with real artifact weights the trivial
//!   fraction is measured, otherwise a QAT-typical default is used;
//! * registers/adders are 8-bit datapath with wider accumulators.

pub mod device;
pub mod estimate;
pub mod timing;

pub use device::{Device, ALVEO_U280, XCVU37P, XCVU9P};
pub use estimate::{estimate_model, EstimatorOpts, FpgaEstimate};
pub use timing::{timing_analytic, TimingEstimate};
