//! Benchmarks for the whole-CNN pipeline simulator (the E12 hot path):
//! continuous-flow vs fully-parallel plans on the trained digits CNN, and
//! the JSC MLP across data rates (Table X timing source).

use cnn_flow::flow::Ratio;
use cnn_flow::quant::QModel;
use cnn_flow::runtime::artifacts_dir;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::new("pipeline");
    let digits = QModel::load(&artifacts_dir().join("weights/digits.json"));
    let jsc = QModel::load(&artifacts_dir().join("weights/jsc.json"));
    let (digits, jsc) = match (digits, jsc) {
        (Ok(d), Ok(j)) => (d, j),
        _ => {
            println!("artifacts not built; skipping pipeline benches");
            return;
        }
    };

    let frames: Vec<Vec<i64>> = digits
        .test_vectors
        .iter()
        .cycle()
        .take(16)
        .map(|tv| tv.x_q.clone())
        .collect();

    let sim = PipelineSim::new(digits.clone(), None).unwrap();
    b.bench_throughput("digits_continuous_flow/16_frames", 16, || {
        black_box(sim.run(&frames).unwrap());
    });

    let reference = PipelineSim::new_reference(digits.clone()).unwrap();
    b.bench_throughput("digits_fully_parallel_ref/16_frames", 16, || {
        black_box(reference.run(&frames).unwrap());
    });

    let jsc_frames: Vec<Vec<i64>> = jsc
        .test_vectors
        .iter()
        .cycle()
        .take(64)
        .map(|tv| tv.x_q.clone())
        .collect();
    for r0 in [Ratio::int(16), Ratio::int(1), Ratio::new(1, 16)] {
        let sim = PipelineSim::new(jsc.clone(), Some(r0)).unwrap();
        b.bench_throughput(
            &format!("jsc_r0_{}/64_frames", r0.paper().replace('/', "_")),
            64,
            || {
                black_box(sim.run(&jsc_frames).unwrap());
            },
        );
    }
}
