//! Benchmarks for the whole-CNN pipeline (the E12 hot path).
//!
//! Two headline comparisons, both on the synthetic digits-shaped fixture
//! so they need no artifacts, both recorded in `BENCH_pipeline.json` (via
//! `util::bench`) so the perf trajectory is tracked across PRs:
//!
//! * the fused pixel-by-pixel interpreter vs the compile-once engine
//!   (`CompiledPipeline` values + `SchedulePrediction` cycles) — the
//!   compiled path must be >= 5x frames/sec;
//! * frame-at-a-time compiled execution vs the batched tier
//!   (`CompiledPipeline::execute_batch`, one program traversal per
//!   batch) — batched must be >= 1.5x single-frame compiled throughput;
//! * the unfolded batched tier vs the rate-aware folded engine
//!   (`FoldedPipeline`, DESIGN.md §9) on the MobileNet-style zoo config,
//!   where the Eq.-8 rate analysis fuses the low-rate tail — folded must
//!   not regress (>= 0.9x, a noise floor; the win itself is tracked in
//!   `BENCH_pipeline.json` as `fold_speedup`);
//! * (unix) the threaded vs evented network cores under fan-in — a
//!   connections-vs-throughput ladder plus a closed-loop RTT probe at
//!   each rung, merged into `BENCH_pipeline.json` under `"fanin"`. At
//!   the 1024-connection rung the evented reactor must not lose to
//!   thread-per-connection (`CNN_FLOW_BENCH_FANIN=0` skips the ladder,
//!   e.g. on fd-limited machines).
//!
//! The original artifact benches (continuous-flow vs fully-parallel
//! plans, JSC across rates) still run when `make artifacts` has.

use cnn_flow::flow::Ratio;
use cnn_flow::model::zoo;
use cnn_flow::quant::QModel;
use cnn_flow::runtime::artifacts_dir;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::bench::{self, black_box, Bencher};
use cnn_flow::util::Rng;

/// Measure one model both ways; iteration = a whole `frames` stream.
fn compare(b: &Bencher, qm: QModel, frames: &[Vec<i64>]) -> bench::EngineComparison {
    let sim = PipelineSim::new(qm, None).unwrap();
    bench::compare_engines(b, &sim, frames)
}

fn main() {
    let b = Bencher::new("pipeline");
    let mut comparisons = Vec::new();

    // --- compiled vs interpreter: synthetic digits-shaped, artifact-free
    let syn = QModel::synthetic(12, 8, 10, 0x51);
    let input_len: usize = syn.input_shape.iter().map(|&d| d.max(1)).product();
    let mut rng = Rng::new(0x52);
    let syn_frames: Vec<Vec<i64>> = (0..16)
        .map(|_| (0..input_len).map(|_| rng.int8() as i64).collect())
        .collect();
    comparisons.push(compare(&b, syn, &syn_frames));

    // --- folded vs unfolded on the MobileNet-style zoo config -----------
    // (the stride-2 tail is where Eq.-8 folding pays: dw2+pw2 / dw3+pw3
    // fuse pairwise and the pool feeds the dense head from registers)
    let mnet = QModel::synthesize(&zoo::mobilenet_micro(), 0x53).unwrap();
    let mnet_len: usize = mnet.input_shape.iter().map(|&d| d.max(1)).product();
    let mnet_frames: Vec<Vec<i64>> = (0..16)
        .map(|_| (0..mnet_len).map(|_| rng.int8() as i64).collect())
        .collect();
    comparisons.push(compare(&b, mnet, &mnet_frames));

    // --- residual zoo configs (DESIGN.md §11): the merge epilogue and
    // DAG buffer pool on the hot path, tracked per engine tier like any
    // other zoo row -------------------------------------------------------
    for model in [zoo::resnet_micro(), zoo::mobilenet_v2_micro()] {
        let qm = QModel::synthesize(&model, 0x54).unwrap();
        let len: usize = qm.input_shape.iter().map(|&d| d.max(1)).product();
        let frames: Vec<Vec<i64>> = (0..16)
            .map(|_| (0..len).map(|_| rng.int8() as i64).collect())
            .collect();
        comparisons.push(compare(&b, qm, &frames));
    }

    // --- artifact models, when built ------------------------------------
    let digits = QModel::load(&artifacts_dir().join("weights/digits.json"));
    let jsc = QModel::load(&artifacts_dir().join("weights/jsc.json"));
    if let (Ok(digits), Ok(jsc)) = (digits, jsc) {
        let frames: Vec<Vec<i64>> = digits
            .test_vectors
            .iter()
            .cycle()
            .take(16)
            .map(|tv| tv.x_q.clone())
            .collect();
        comparisons.push(compare(&b, digits.clone(), &frames));

        let sim = PipelineSim::new(digits.clone(), None).unwrap();
        b.bench_throughput("digits_continuous_flow/16_frames", 16, || {
            black_box(sim.run(&frames).unwrap());
        });
        let reference = PipelineSim::new_reference(digits).unwrap();
        b.bench_throughput("digits_fully_parallel_ref/16_frames", 16, || {
            black_box(reference.run(&frames).unwrap());
        });

        let jsc_frames: Vec<Vec<i64>> = jsc
            .test_vectors
            .iter()
            .cycle()
            .take(64)
            .map(|tv| tv.x_q.clone())
            .collect();
        for r0 in [Ratio::int(16), Ratio::int(1), Ratio::new(1, 16)] {
            let sim = PipelineSim::new(jsc.clone(), Some(r0)).unwrap();
            b.bench_throughput(
                &format!("jsc_r0_{}/64_frames", r0.paper().replace('/', "_")),
                64,
                || {
                    black_box(sim.run(&jsc_frames).unwrap());
                },
            );
        }
    } else {
        println!("artifacts not built; skipping artifact pipeline benches");
    }

    bench::write_pipeline_bench_json(std::path::Path::new("BENCH_pipeline.json"), &comparisons)
        .expect("write BENCH_pipeline.json");
    for c in &comparisons {
        println!(
            "BENCH pipeline/{}/speedup compiled={:.3}M frames/s interp={:.3}M frames/s speedup={:.2}x batched={:.3}M frames/s batch_speedup={:.2}x folded={:.3}M frames/s fold_speedup={:.2}x narrow={}",
            c.model,
            c.compiled_fps() / 1e6,
            c.interp_fps() / 1e6,
            c.speedup(),
            c.batched_fps() / 1e6,
            c.batch_speedup(),
            c.folded_fps() / 1e6,
            c.fold_speedup(),
            c.narrow,
        );
    }
    let syn_speedup = comparisons[0].speedup();
    assert!(
        syn_speedup >= 5.0,
        "compiled path must be >= 5x the interpreter on the synthetic digits \
         fixture (got {syn_speedup:.2}x)"
    );
    let batch_speedup = comparisons[0].batch_speedup();
    assert!(
        batch_speedup >= 1.5,
        "batched execution must be >= 1.5x single-frame compiled throughput \
         on the synthetic digits fixture (got {batch_speedup:.2}x)"
    );
    // Value equality folded-vs-unfolded is asserted inside
    // `compare_engines`; here we pin that folding never *costs* throughput
    // (0.9 floor absorbs timer noise on the small fixture — the actual
    // win lands in BENCH_pipeline.json as fold_speedup).
    let fold_speedup = comparisons[1].fold_speedup();
    assert!(
        fold_speedup >= 0.9,
        "rate-aware folding must not regress the batched tier on \
         mobilenet_micro (got {fold_speedup:.2}x)"
    );
    println!(
        "OK: compiled engine {syn_speedup:.1}x interpreter, batched tier \
         {batch_speedup:.1}x single-frame, folded tier {fold_speedup:.2}x \
         batched on mobilenet_micro; BENCH_pipeline.json written"
    );

    // --- network fan-in: threaded vs evented core ------------------------
    #[cfg(unix)]
    {
        let skip = std::env::var("CNN_FLOW_BENCH_FANIN").is_ok_and(|v| v == "0");
        if skip {
            println!("CNN_FLOW_BENCH_FANIN=0: skipping the network fan-in ladder");
        } else {
            let rungs = [64usize, 256, 1024];
            let rows = cnn_flow::net::fanin::ladder(&rungs, 16).expect("fan-in ladder");
            for r in &rows {
                println!(
                    "BENCH pipeline/fanin/{}_conns threaded={:.0} req/s evented={:.0} req/s \
                     ratio={:.2}x threaded_p99={:.0}us evented_p99={:.0}us",
                    r.connections,
                    r.threaded_rps,
                    r.evented_rps,
                    r.rps_ratio(),
                    r.threaded_rtt_p99_us,
                    r.evented_rtt_p99_us,
                );
            }
            bench::merge_fanin_bench_json(std::path::Path::new("BENCH_pipeline.json"), &rows)
                .expect("merge fanin into BENCH_pipeline.json");
            let top = rows.last().expect("ladder has rungs");
            assert!(
                top.rps_ratio() >= 1.0,
                "the evented core must not lose to thread-per-connection at \
                 {} connections (got {:.2}x)",
                top.connections,
                top.rps_ratio()
            );
            println!(
                "OK: evented core {:.2}x threaded at {} connections; fanin rows merged",
                top.rps_ratio(),
                top.connections
            );
        }
    }
}
