//! Throughput scaling of the sharded serving coordinator.
//!
//! Replays one seeded trace (synthetic fixture — no artifacts needed)
//! against servers with 1/2/4/8 worker shards and reports both wall-clock
//! replay time and the *simulated* aggregate throughput (completed frames
//! over the max per-shard busy cycles at the modelled clock). Every
//! response is checked bit-for-bit against the single-`PipelineSim`
//! golden path, and the run asserts >= 2x aggregate throughput at
//! 4 workers vs 1.
//!
//! Output is grep-stable: one `BENCH coordinator/...` line per
//! configuration.

use std::time::{Duration, Instant};

use cnn_flow::coordinator::{loadgen, Server, ServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;

fn main() {
    println!("# bench group: coordinator");
    let qm = QModel::synthetic(12, 8, 10, 0xBE);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let trace = loadgen::Trace::seeded(0xB0, 256, 144, 0);
    let expected = loadgen::golden_outputs(&golden, &trace);

    let mut base_fps = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 8,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let started = Instant::now();
        let report = loadgen::replay(&server, &trace, 32, Some(&expected));
        let wall = started.elapsed();
        server.drain();
        let shards = server.shard_metrics();
        let m = server.metrics();
        assert_eq!(report.ok, 256, "workers={workers}: not all requests served");
        assert_eq!(
            report.mismatched, 0,
            "workers={workers}: responses diverged from the golden sim"
        );
        assert_eq!(m.completed, 256);
        if workers == 1 {
            base_fps = m.aggregate_fps;
        }
        let busy_max = shards.iter().map(|s| s.busy_cycles).max().unwrap_or(0);
        println!(
            "BENCH coordinator/workers={workers} wall={wall:?} \
             aggregate={:.3}M inf/s speedup={:.2}x busy_max={busy_max} \
             mean_batch={:.1} p50={:?} p99={:?}",
            m.aggregate_fps / 1e6,
            m.aggregate_fps / base_fps,
            m.mean_batch,
            m.p50,
            m.p99,
        );
        if workers == 4 {
            assert!(
                m.aggregate_fps >= 2.0 * base_fps,
                "4 workers did not reach 2x the single-shard simulated throughput \
                 ({:.0} vs {:.0})",
                m.aggregate_fps,
                base_fps
            );
        }
    }
    println!("OK: simulated throughput scales with worker count");
}
