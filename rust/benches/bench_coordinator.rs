//! Throughput scaling of the sharded serving coordinator.
//!
//! Replays one seeded trace (synthetic fixture — no artifacts needed)
//! against servers with 1/2/4/8 worker shards and reports both wall-clock
//! replay time and the *simulated* aggregate throughput (completed frames
//! over the max per-shard busy cycles at the modelled clock). Every
//! response is checked bit-for-bit against the single-`PipelineSim`
//! golden path, and the run asserts >= 2x aggregate throughput at
//! 4 workers vs 1.
//!
//! Output is grep-stable: one `BENCH coordinator/...` line per
//! configuration.

//! A second, mixed-traffic case replays one seeded **heterogeneous**
//! trace (three serving-zoo models) through a multi-model server with
//! per-model shard groups, checks every response against each model's own
//! golden sim, and reports per-model + aggregate figures — one `BENCH
//! coordinator/mixed/...` line per model.
//!
//! A third case measures the TCP front-end's tax: the median round-trip
//! of one blocking request in-process (`Server::infer`) vs over a
//! localhost socket (`net::Client::infer` against a `NetServer` on
//! 127.0.0.1), merged into `BENCH_pipeline.json` under `"net"` so the
//! socket overhead is tracked across PRs next to the engine numbers.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cnn_flow::coordinator::{loadgen, Server, ServerConfig};
use cnn_flow::model::zoo;
use cnn_flow::net::{Client, NetServer};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::bench::{self, black_box, BenchOpts, Bencher, NetComparison};

fn main() {
    println!("# bench group: coordinator");
    let qm = QModel::synthetic(12, 8, 10, 0xBE);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let trace = loadgen::Trace::seeded(0xB0, 256, 144, 0);
    let expected = loadgen::golden_outputs(&golden, &trace);

    let mut base_fps = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 8,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let started = Instant::now();
        let report = loadgen::replay(&server, &trace, 32, Some(&expected));
        let wall = started.elapsed();
        server.drain();
        let shards = server.shard_metrics();
        let m = server.metrics();
        assert_eq!(report.ok, 256, "workers={workers}: not all requests served");
        assert_eq!(
            report.mismatched, 0,
            "workers={workers}: responses diverged from the golden sim"
        );
        assert_eq!(m.completed, 256);
        if workers == 1 {
            base_fps = m.aggregate_fps;
        }
        let busy_max = shards.iter().map(|s| s.busy_cycles).max().unwrap_or(0);
        println!(
            "BENCH coordinator/workers={workers} wall={wall:?} \
             aggregate={:.3}M inf/s speedup={:.2}x busy_max={busy_max} \
             mean_batch={:.1} p50={:?} p99={:?}",
            m.aggregate_fps / 1e6,
            m.aggregate_fps / base_fps,
            m.mean_batch,
            m.p50,
            m.p99,
        );
        if workers == 4 {
            assert!(
                m.aggregate_fps >= 2.0 * base_fps,
                "4 workers did not reach 2x the single-shard simulated throughput \
                 ({:.0} vs {:.0})",
                m.aggregate_fps,
                base_fps
            );
        }
    }
    println!("OK: simulated throughput scales with worker count");

    // --- mixed-traffic multi-model case --------------------------------
    let zoo_models = [zoo::digits_cnn(), zoo::mobilenet_micro(), zoo::vgg_micro()];
    let mut sims: Vec<(String, PipelineSim)> = Vec::new();
    for (i, m) in zoo_models.iter().enumerate() {
        let qm = QModel::synthesize(m, 0xBEA7 + i as u64).unwrap();
        sims.push((m.name.clone(), PipelineSim::new(qm, None).unwrap()));
    }
    let specs: Vec<(String, usize)> = sims
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect();
    let trace = loadgen::MultiTrace::seeded(0x317D, 192, &specs, 0);
    let golden_refs: Vec<&PipelineSim> = sims.iter().map(|(_, s)| s).collect();
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);
    let bundles: Vec<(String, PipelineSim)> = sims
        .iter()
        .map(|(id, sim)| (id.clone(), sim.clone()))
        .collect();
    let mut server = Server::start_multi(
        bundles,
        ServerConfig {
            workers: 2, // per model: 3 groups x 2 shards
            max_batch: 8,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_micros(200),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let started = Instant::now();
    let report = loadgen::replay_multi(&server, &trace, 24, Some(&expected));
    let wall = started.elapsed();
    server.drain();
    let m = server.metrics();
    assert_eq!(report.aggregate.ok, 192, "mixed: not all requests served");
    assert_eq!(
        report.aggregate.mismatched, 0,
        "mixed: responses diverged from the per-model golden sims"
    );
    assert_eq!(m.completed, 192);
    assert_eq!(
        m.occupancy_frames,
        m.completed + m.errored,
        "mixed: batch accounting must reconcile"
    );
    for (mm, rep) in server.model_metrics().iter().zip(&report.per_model) {
        assert_eq!(
            mm.metrics.completed, rep.ok,
            "mixed: {} completed != replay ok",
            mm.model
        );
        println!(
            "BENCH coordinator/mixed/{} completed={} batches={} mean_batch={:.1} \
             aggregate={:.3}M inf/s p99={:?}",
            mm.model,
            mm.metrics.completed,
            mm.metrics.batches,
            mm.metrics.mean_batch,
            mm.metrics.aggregate_fps / 1e6,
            mm.metrics.p99,
        );
    }
    println!(
        "BENCH coordinator/mixed/aggregate wall={wall:?} completed={} \
         aggregate={:.3}M inf/s models={}",
        m.completed,
        m.aggregate_fps / 1e6,
        m.models,
    );
    println!("OK: mixed 3-model traffic served bit-exactly with reconciled metrics");

    // --- localhost round-trip vs in-process submit overhead ------------
    let qm = QModel::synthetic(8, 4, 6, 0x7C9);
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 1,
                max_batch: 1,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let model = coord.models()[0].clone();
    let frame = vec![1i64; 64];
    let expect = coord.infer(frame.clone()).unwrap().logits;
    let b = Bencher::with_opts(
        "coordinator",
        BenchOpts {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(250),
            max_iters: 50_000,
        },
    );
    let inproc_rtt_ns = b.bench("inproc_rtt", || {
        black_box(coord.infer(frame.clone()).unwrap());
    });
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 1).unwrap();
    // Sanity: the socket path answers bit-identically before we time it.
    assert_eq!(client.infer(&model, &frame).unwrap().logits, expect);
    let tcp_rtt_ns = b.bench("tcp_rtt", || {
        black_box(client.infer(&model, &frame).unwrap());
    });
    let snap = net.shutdown();
    assert_eq!(snap.errors_total(), 0, "net bench saw protocol errors");
    let cmp = NetComparison {
        inproc_rtt_ns,
        tcp_rtt_ns,
    };
    println!(
        "BENCH coordinator/net inproc_rtt={:.2}us tcp_rtt={:.2}us \
         overhead={:.2}us ratio={:.1}x",
        cmp.inproc_rtt_ns / 1e3,
        cmp.tcp_rtt_ns / 1e3,
        cmp.overhead_ns() / 1e3,
        cmp.overhead_ratio(),
    );
    bench::merge_net_bench_json(std::path::Path::new("BENCH_pipeline.json"), &cmp)
        .expect("merge net figures into BENCH_pipeline.json");
    println!("OK: localhost round-trip measured and merged into BENCH_pipeline.json");
}
