//! Benchmarks for the structural unit simulators (Tables I-IV machinery):
//! KPU / PPU / FCU ticks, single- and multi-configuration, plus full
//! trace generation + oracle verification.

use cnn_flow::sim::fcu::{fcu_rom, Fcu};
use cnn_flow::sim::trace::{trace_kpu, verify_kpu_trace, KpuTraceCfg};
use cnn_flow::sim::{Kpu, Ppu};
use cnn_flow::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::new("sim_units");

    // KPU tick throughput: one frame of a 28x28 map through a 3x3 kernel.
    for configs in [1usize, 4, 16] {
        let weights: Vec<Vec<i64>> = (0..configs)
            .map(|c| (0..9).map(|i| (c * 9 + i) as i64 % 7 - 3).collect())
            .collect();
        let mut kpu = Kpu::new(3, 28, 1, weights);
        let frame: Vec<i64> = (0..28 * 28).map(|i| (i % 255) as i64 - 127).collect();
        b.bench_throughput(
            &format!("kpu_tick/k3_f28_C{configs}"),
            frame.len() as u64,
            || {
                for (i, &x) in frame.iter().enumerate() {
                    black_box(kpu.tick(x, Some(i % 28)));
                }
            },
        );
    }

    // PPU tick throughput.
    let mut ppu = Ppu::new(2, 28, 4);
    let frame: Vec<i64> = (0..28 * 28).map(|i| (i * 31 % 255) as i64 - 127).collect();
    b.bench_throughput("ppu_tick/k2_f28_C4", frame.len() as u64, || {
        for &x in &frame {
            black_box(ppu.tick(x));
        }
    });

    // FCU: the running example's F1 (j=4, h=5, 256 inputs, C=320).
    let w: Vec<Vec<i64>> = (0..10)
        .map(|n| (0..256).map(|m| ((n * 256 + m) % 13) as i64 - 6).collect())
        .collect();
    let rom = fcu_rom(&w, 0, 4, 5, 256);
    let mut fcu = Fcu::new(4, 5, 256, rom, vec![0; 5]);
    let lanes: Vec<[i64; 4]> = (0..64).map(|i| [i, i + 1, i + 2, i + 3]).collect();
    b.bench_throughput("fcu_tick/f1_j4_h5", 320, || {
        for lane in &lanes {
            for _ in 0..5 {
                black_box(fcu.tick(lane));
            }
        }
    });

    // Full trace generation + verification (Table II).
    b.bench("trace_table2_verified", || {
        let t = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 1,
            s: 1,
            cycles: 37,
        });
        black_box(verify_kpu_trace(&t).unwrap());
    });
}
