//! Benchmarks for the analysis stack: rate propagation, interleave
//! planning, and the complexity model over every zoo model — the code
//! paths behind Tables V-VIII. Regenerating Table VIII end to end is also
//! timed, since the paper's code generator runs this per design iteration.

use cnn_flow::complexity::{model_cost, parallel::fully_parallel_cost, CostOpts};
use cnn_flow::flow::{analyze, plan_all};
use cnn_flow::model::zoo;
use cnn_flow::report::tables;
use cnn_flow::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::new("analysis");

    for model in [zoo::running_example(), zoo::mobilenet_v1(100), zoo::resnet18()] {
        let name = model.name.clone();
        b.bench(&format!("rate_analysis/{name}"), || {
            black_box(analyze(&model, None).unwrap());
        });
        let analysis = analyze(&model, None).unwrap();
        b.bench(&format!("plan/{name}"), || {
            black_box(plan_all(&analysis));
        });
        let plans = plan_all(&analysis);
        b.bench(&format!("complexity/{name}"), || {
            black_box(model_cost(&plans, CostOpts::FULL));
        });
        b.bench(&format!("fully_parallel_ref/{name}"), || {
            black_box(fully_parallel_cost(&analysis, CostOpts::FULL));
        });
    }

    b.bench("table5_full", || {
        black_box(tables::table5());
    });
    b.bench("table8_full", || {
        black_box(tables::table8());
    });
}
