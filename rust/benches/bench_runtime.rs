//! Benchmarks for the serving path: PJRT golden-model execution latency
//! and coordinator round-trip latency/throughput (batched vs unbatched).

use std::sync::Arc;

use cnn_flow::coordinator::{Server, ServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::runtime::{artifacts_dir, ModelBundle, Runtime};
use cnn_flow::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::new("runtime");
    if !artifacts_dir().join("meta.json").exists() {
        println!("artifacts not built; skipping runtime benches");
        return;
    }
    if cfg!(not(feature = "pjrt-xla")) {
        println!("pjrt-xla backend disabled; skipping runtime benches");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");

    for name in ["digits", "jsc"] {
        let bundle = ModelBundle::load(&rt, name).unwrap();
        let tv = &bundle.qmodel.test_vectors[0];
        let x: Vec<f32> = tv.x_q.iter().map(|&v| v as f32).collect();
        b.bench(&format!("pjrt_execute/{name}"), || {
            black_box(bundle.golden.run_f32(&x).unwrap());
        });
    }

    // Coordinator round trip (single client, batch of 1).
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let x = qm.test_vectors[0].x_q.clone();
    let server = Server::start(
        qm.clone(),
        ServerConfig {
            max_batch: 1,
            verify_every: 0,
            batch_deadline: std::time::Duration::from_micros(0),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    b.bench("coordinator_roundtrip/batch1", || {
        black_box(server.infer(x.clone()).unwrap());
    });
    drop(server);

    // Batched throughput: 8 concurrent clients, batch up to 16.
    let server = Arc::new(
        Server::start(
            qm.clone(),
            ServerConfig {
                max_batch: 16,
                verify_every: 0,
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    b.bench_throughput("coordinator_8_clients/64_reqs", 64, || {
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = Arc::clone(&server);
            let xi = x.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    let _ = s.infer(xi.clone());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
