//! Benchmarks for the FPGA estimator sweeps behind Tables IX/X and
//! Fig. 13 (the design-space-exploration hot path a user iterates on).

use cnn_flow::flow::{analyze, plan_all};
use cnn_flow::fpga::{estimate_model, EstimatorOpts};
use cnn_flow::model::zoo;
use cnn_flow::report::synthesis::{fig13, jsc_sweep, load_jsc_artifact, table9};
use cnn_flow::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::new("estimator");

    let analysis = analyze(&zoo::mobilenet_v1(100), None).unwrap();
    let plans = plan_all(&analysis);
    b.bench("estimate_mobilenet", || {
        black_box(estimate_model(&plans, EstimatorOpts::default(), None));
    });

    b.bench("table9", || {
        black_box(table9());
    });

    let qm = load_jsc_artifact();
    b.bench("jsc_sweep_18_points", || {
        black_box(jsc_sweep(qm.as_ref()));
    });

    b.bench("fig13_pareto", || {
        black_box(fig13(qm.as_ref()));
    });
}
