"""L1 Pallas dense / pointwise kernel.

The grid mirrors the paper's FCU decomposition (Section III-E): each grid
step computes one block of `h` neurons over all input features — one
"physical FCU" worth of work — so the HBM->VMEM weight traffic follows the
same weight-ROM-per-unit layout the hardware uses. The per-block
contraction is a (h, features) x (features,) matvec, which on a real TPU
batches onto the MXU; interpret=True is required for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_block_kernel(x_ref, w_ref, b_ref, o_ref):
    """One block of neurons: w_ref (h, F), x_ref (F,), o_ref (h,)."""
    o_ref[:] = (
        jnp.dot(w_ref[:, :], x_ref[:], preferred_element_type=jnp.float32)
        + b_ref[:]
    )


@functools.partial(jax.jit, static_argnames=("block",))
def dense_pallas(x, w, b, block: int = 0):
    """Pallas dense layer: x (F,), w (U, F), b (U,) -> (U,).

    `block` is the neuron-block size h; 0 picks the whole layer (one FCU).
    Must divide U.
    """
    units, feats = w.shape
    h = block if block else units
    assert units % h == 0, "block must divide the unit count"
    return pl.pallas_call(
        _dense_block_kernel,
        grid=(units // h,),
        in_specs=[
            pl.BlockSpec((feats,), lambda i: (0,)),
            pl.BlockSpec((h, feats), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((h,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((units,), jnp.float32),
        interpret=True,
    )(x, w, b)
