"""L1 Pallas convolution kernel with *implicit* zero padding.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's KPU is a
transposed-form FIR sized for FPGA DSP blocks, with padding implemented by
masking multiplier columns (Fig. 4) so the input stream never carries
explicit zeros. On a TPU-shaped target the same insight — *mask instead of
materialise* — becomes predicated loads: the kernel below never builds a
padded copy of the feature map in VMEM; every window element is gathered
with a clamped index and multiplied by a validity mask, which is exactly
the `pad_i(c)` select of Eq. 10 vectorised over the output row.

The paper's line buffers (one row fetched once, reused by k window rows)
map to the BlockSpec schedule: the grid walks output rows, and the row
block brought HBM->VMEM for output row r is reused by the k shifted MACs.
On a real TPU the channel contraction below lands on the MXU as a
(out_w, Cin) x (Cin, Cout) matmul per tap; here we run interpret=True
because the CPU PJRT plugin cannot execute Mosaic custom calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, k, stride, padding, out_w):
    """Compute one output row r = program_id(0).

    x_ref: (H, W, Cin) — resident input map (small models; see module doc).
    w_ref: (k, k, Cin, Cout); b_ref: (Cout,); o_ref: (1, out_w, Cout).
    """
    r = pl.program_id(0)
    h, w_in, cin = x_ref.shape
    cout = o_ref.shape[-1]
    acc = jnp.zeros((out_w, cout), jnp.float32)
    # Column gather indices for each tap column v: ow*s + v - p.
    base = jnp.arange(out_w) * stride - padding
    for u in range(k):
        row_idx = r * stride + u - padding
        row_ok = (row_idx >= 0) & (row_idx < h)
        row = x_ref[jnp.clip(row_idx, 0, h - 1), :, :]  # (W, Cin)
        row = jnp.where(row_ok, row, 0.0)
        for v in range(k):
            idx = base + v
            col_ok = (idx >= 0) & (idx < w_in)  # the pad_v(c) mask, Eq. 10
            taps = jnp.take(row, jnp.clip(idx, 0, w_in - 1), axis=0)
            taps = jnp.where(col_ok[:, None], taps, 0.0)  # (out_w, Cin)
            # Channel contraction: MXU matmul on TPU.
            acc = acc + jnp.dot(
                taps, w_ref[u, v, :, :], preferred_element_type=jnp.float32
            )
    o_ref[0, :, :] = acc + b_ref[:]


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_pallas(x, w, b, stride: int = 1, padding: int = 0):
    """Pallas conv2d: x (H,W,Cin), w (k,k,Cin,Cout), b (Cout,)."""
    k = w.shape[0]
    h, w_in, _ = x.shape
    cout = w.shape[3]
    out_h = (h + 2 * padding - k) // stride + 1
    out_w = (w_in + 2 * padding - k) // stride + 1
    kernel = functools.partial(
        _conv_row_kernel, k=k, stride=stride, padding=padding, out_w=out_w
    )
    return pl.pallas_call(
        kernel,
        grid=(out_h,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda r: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda r: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((1, out_w, cout), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, cout), jnp.float32),
        interpret=True,
    )(x, w, b)


def _dwconv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, k, stride, padding, out_w):
    """Depthwise variant: w_ref (k, k, C); one output row per program."""
    r = pl.program_id(0)
    h, w_in, c = x_ref.shape
    acc = jnp.zeros((out_w, c), jnp.float32)
    base = jnp.arange(out_w) * stride - padding
    for u in range(k):
        row_idx = r * stride + u - padding
        row_ok = (row_idx >= 0) & (row_idx < h)
        row = x_ref[jnp.clip(row_idx, 0, h - 1), :, :]
        row = jnp.where(row_ok, row, 0.0)
        for v in range(k):
            idx = base + v
            col_ok = (idx >= 0) & (idx < w_in)
            taps = jnp.take(row, jnp.clip(idx, 0, w_in - 1), axis=0)
            taps = jnp.where(col_ok[:, None], taps, 0.0)
            acc = acc + taps * w_ref[u, v, :]
    o_ref[0, :, :] = acc + b_ref[:]


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def depthwise_conv2d_pallas(x, w, b, stride: int = 1, padding: int = 0):
    """Pallas depthwise conv: x (H,W,C), w (k,k,C), b (C,)."""
    k = w.shape[0]
    h, w_in, c = x.shape
    out_h = (h + 2 * padding - k) // stride + 1
    out_w = (w_in + 2 * padding - k) // stride + 1
    kernel = functools.partial(
        _dwconv_row_kernel, k=k, stride=stride, padding=padding, out_w=out_w
    )
    return pl.pallas_call(
        kernel,
        grid=(out_h,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda r: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda r: (0, 0, 0)),
            pl.BlockSpec(b.shape, lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((1, out_w, c), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, c), jnp.float32),
        interpret=True,
    )(x, w, b)
