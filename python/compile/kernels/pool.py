"""L1 Pallas max-pooling kernel (the PPU of Fig. 5, Eq. 6).

Same row-grid schedule as the conv kernel: the paper's PPU line buffer
(each input row read once, reused by the k vertical window positions)
becomes the BlockSpec row walk; the horizontal window max is a strided
gather + elementwise max, fully vectorised over the output row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_row_kernel(x_ref, o_ref, *, k, stride, out_w):
    r = pl.program_id(0)
    _, w_in, c = x_ref.shape
    acc = jnp.full((out_w, c), -jnp.inf, jnp.float32)
    base = jnp.arange(out_w) * stride
    for u in range(k):
        row = x_ref[r * stride + u, :, :]
        for v in range(k):
            taps = jnp.take(row, base + v, axis=0)
            acc = jnp.maximum(acc, taps)
    o_ref[0, :, :] = acc


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def maxpool2d_pallas(x, k: int, stride: int):
    """Pallas max pool: x (H,W,C) -> (H',W',C)."""
    h, w_in, c = x.shape
    out_h = (h - k) // stride + 1
    out_w = (w_in - k) // stride + 1
    kernel = functools.partial(_maxpool_row_kernel, k=k, stride=stride, out_w=out_w)
    return pl.pallas_call(
        kernel,
        grid=(out_h,),
        in_specs=[pl.BlockSpec(x.shape, lambda r: (0, 0, 0))],
        out_specs=pl.BlockSpec((1, out_w, c), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, c), jnp.float32),
        interpret=True,
    )(x)
