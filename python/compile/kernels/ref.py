"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness base).

Everything here is written with the most obvious jnp formulation possible —
no tiling, no pallas — so that `pytest python/tests/` can assert the Pallas
kernels (and, transitively, the AOT-compiled HLO the rust runtime executes)
against an independently simple implementation.

Layouts follow the rust simulator: feature maps are (H, W, C) row-major,
dense inputs are flat (features,), weights are:

* conv:      (k, k, C_in, C_out)
* depthwise: (k, k, C)
* dense:     (units, features)   [neuron-major, matching rust `fcu_rom`]
"""

from __future__ import annotations

import jax.numpy as jnp


def conv2d(x, w, b=None, stride: int = 1, padding: int = 0):
    """Valid convolution with explicit zero padding.

    x: (H, W, C_in); w: (k, k, C_in, C_out); returns (H', W', C_out) with
    H' = (H + 2p - k)//s + 1. Matches Eq. 2 plus Section III-B padding.
    """
    k = w.shape[0]
    if padding:
        x = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    h, wdt, _ = x.shape
    out_h = (h - k) // stride + 1
    out_w = (wdt - k) // stride + 1
    rows = []
    for r in range(out_h):
        cols = []
        for c in range(out_w):
            window = x[r * stride : r * stride + k, c * stride : c * stride + k, :]
            # (k,k,Cin) x (k,k,Cin,Cout) -> (Cout,)
            cols.append(jnp.tensordot(window, w, axes=([0, 1, 2], [0, 1, 2])))
        rows.append(jnp.stack(cols))
    y = jnp.stack(rows)
    if b is not None:
        y = y + b
    return y


def depthwise_conv2d(x, w, b=None, stride: int = 1, padding: int = 0):
    """Depthwise convolution: x (H,W,C), w (k,k,C) -> (H',W',C)."""
    k = w.shape[0]
    if padding:
        x = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    h, wdt, _ = x.shape
    out_h = (h - k) // stride + 1
    out_w = (wdt - k) // stride + 1
    rows = []
    for r in range(out_h):
        cols = []
        for c in range(out_w):
            window = x[r * stride : r * stride + k, c * stride : c * stride + k, :]
            cols.append(jnp.sum(window * w, axis=(0, 1)))
        rows.append(jnp.stack(cols))
    y = jnp.stack(rows)
    if b is not None:
        y = y + b
    return y


def maxpool2d(x, k: int, stride: int):
    """Max pooling, x (H,W,C) -> (H',W',C) (Eq. 6)."""
    h, w, _ = x.shape
    out_h = (h - k) // stride + 1
    out_w = (w - k) // stride + 1
    rows = []
    for r in range(out_h):
        cols = []
        for c in range(out_w):
            window = x[r * stride : r * stride + k, c * stride : c * stride + k, :]
            cols.append(jnp.max(window, axis=(0, 1)))
        rows.append(jnp.stack(cols))
    return jnp.stack(rows)


def avgpool2d(x, k: int, stride: int):
    """Average pooling (Section VI: a depthwise conv with weights 1/k^2)."""
    h, w, _ = x.shape
    out_h = (h - k) // stride + 1
    out_w = (w - k) // stride + 1
    rows = []
    for r in range(out_h):
        cols = []
        for c in range(out_w):
            window = x[r * stride : r * stride + k, c * stride : c * stride + k, :]
            cols.append(jnp.mean(window, axis=(0, 1)))
        rows.append(jnp.stack(cols))
    return jnp.stack(rows)


def dense(x, w, b=None):
    """Fully connected layer (Eq. 7): x (features,), w (units, features)."""
    y = w @ x
    if b is not None:
        y = y + b
    return y


def relu(x):
    return jnp.maximum(x, 0.0)


# ---------------------------------------------------------------------------
# Symmetric int8 fixed-point quantization — shared semantics with
# rust/src/quant (scale = amax / 127, zero point 0, round-half-away).
# ---------------------------------------------------------------------------

QMAX = 127.0


def quant_scale(amax):
    """Scale for symmetric int8: amax -> scale with q = round(x / scale)."""
    return jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-8) / QMAX


def quantize(x, scale):
    """Float -> int8 grid (returned as float int values for jax grads)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -QMAX, QMAX)


def dequantize(q, scale):
    return q * scale


def fake_quant(x, scale):
    """Quantize-dequantize with a straight-through estimator gradient."""
    import jax

    q = dequantize(quantize(x, scale), scale)
    # STE: forward q, backward identity.
    return x + jax.lax.stop_gradient(q - x)
