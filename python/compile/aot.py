"""AOT compile path (run once by `make artifacts`; never on the request path).

Trains the end-to-end models (QAT int8), then exports:

* `artifacts/digits_int8.hlo.txt`  — bit-exact int8 golden pipeline of the
  digits CNN (the graph the rust coordinator serves and checks the cycle
  simulator against);
* `artifacts/digits_float.hlo.txt` — float forward built from the L1
  *Pallas* kernels (interpret mode), proving the pallas -> HLO -> PJRT
  path end to end;
* `artifacts/jsc_int8.hlo.txt`     — int8 golden for the JSC MLP;
* `artifacts/weights/{digits,jsc}.json` — quantized layers (int8 weights,
  int32 bias, f32 requant multipliers) + held-out test vectors for the
  rust integration tests;
* `artifacts/meta.json`            — index + accuracies.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as datasets
from .model import forward_float, forward_int8
from .train import train_digits, train_jsc


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides big literals as '{...}', which
    # the text parser on the rust side would silently zero-fill — the
    # weights must be printed in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's text parser predates jax's extended source
    # metadata attributes (source_end_line etc.) — strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def lower_int8(qlayers, in_shape):
    fn = functools.partial(forward_int8, qlayers)
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return jax.jit(lambda x: (fn(x),)).lower(spec)


def lower_float_pallas(spec_model, params, in_shape):
    fn = lambda x: (forward_float(spec_model, params, x, use_pallas=True),)
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return jax.jit(fn).lower(spec)


def export_model(name, spec, params, scales, acc, xs_test, out_dir):
    """Quantize, lower, dump weights + test vectors. Returns meta entry."""
    from .model import export_qlayers, layer_shapes

    qlayers = export_qlayers(spec, params, scales)
    in_shape = layer_shapes(spec)[0][0]
    if spec.layers[0].kind == "dense":
        in_shape = (1, 1, in_shape[2])

    # Int8 golden HLO.
    hlo_int8 = to_hlo_text(lower_int8(qlayers, in_shape))
    int8_path = os.path.join(out_dir, f"{name}_int8.hlo.txt")
    with open(int8_path, "w") as f:
        f.write(hlo_int8)

    # Test vectors: quantized inputs -> int8-pipeline outputs.
    s_in = scales["input"]
    vectors = []
    for x in xs_test:
        x_q = np.clip(np.round(np.asarray(x) / s_in), -127, 127).astype(np.float32)
        y = np.asarray(forward_int8(qlayers, jnp.asarray(x_q.reshape(in_shape))))
        vectors.append(
            {
                "x_q": [int(v) for v in x_q.reshape(-1)],
                "y": [float(v) for v in y.reshape(-1)],
            }
        )

    weights = {
        "name": name,
        "input_shape": list(in_shape),
        "input_scale": float(np.float32(s_in)),
        "layers": [ql.to_json_dict() for ql in qlayers],
        "test_vectors": vectors,
        "qat_accuracy": acc,
    }
    wpath = os.path.join(out_dir, "weights", f"{name}.json")
    with open(wpath, "w") as f:
        json.dump(weights, f)
    return {
        "int8_hlo": os.path.basename(int8_path),
        "weights": f"weights/{name}.json",
        "qat_accuracy": acc,
        "input_shape": list(in_shape),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--digits-train", type=int, default=1500)
    ap.add_argument("--jsc-train", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    meta = {"models": {}}

    # --- digits CNN (E12 end-to-end model) -------------------------------
    print("training digits_cnn (QAT int8)...")
    spec, params, scales, acc = train_digits(args.digits_train, seed=args.seed)
    print(f"  digits QAT accuracy: {acc:.4f}")
    xs_test, _ = datasets.digits(16, seed=args.seed + 999)
    meta["models"]["digits"] = export_model(
        "digits", spec, params, scales, acc, xs_test, out_dir
    )

    # Float-pallas HLO for the digits model (L1 kernels in the graph).
    hlo = to_hlo_text(lower_float_pallas(spec, params, (12, 12, 1)))
    with open(os.path.join(out_dir, "digits_float.hlo.txt"), "w") as f:
        f.write(hlo)
    meta["models"]["digits"]["float_hlo"] = "digits_float.hlo.txt"

    # --- JSC MLP (Table X model) -----------------------------------------
    print("training jsc_mlp (QAT int8)...")
    jspec, jparams, jscales, jacc = train_jsc(args.jsc_train, seed=args.seed)
    print(f"  jsc QAT accuracy: {jacc:.4f}")
    xs_test, _ = datasets.jsc(16, seed=args.seed + 999)
    meta["models"]["jsc"] = export_model(
        "jsc", jspec, jparams, jscales, jacc, xs_test.reshape(-1, 1, 1, 16), out_dir
    )

    # Back-compat main artifact name used by the Makefile dependency.
    import shutil

    shutil.copyfile(
        os.path.join(out_dir, "digits_int8.hlo.txt"),
        os.path.join(out_dir, "model.hlo.txt"),
    )

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"artifacts written to {out_dir}")


if __name__ == "__main__":
    main()
