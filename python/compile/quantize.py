"""Symmetric int8 quantization substrate (L2), shared bit-for-bit with the
rust pipeline simulator (`rust/src/quant`).

Scheme (matching the paper's 8-bit fixed-point MQUAT setup):

* per-tensor symmetric int8: q = clamp(round(x / s), -127, 127), zero point 0;
* accumulators are int32 (int64 in the rust sim — the models here never
  exceed int32);
* bias is quantized at the accumulator scale: b_q = round(b / (s_x * s_w));
* requantization to the next layer's activation scale uses a single f32
  multiplier M = s_x * s_w / s_y applied as
  `y_q = clamp(half_away_round(acc * M), -127, 127)`.

`half_away_round` (round half away from zero) is chosen because both
`jnp`-side emulation and the rust side can implement it identically —
`jnp.round` alone would give half-even. The rust sim replays exactly this
f32 arithmetic, so integration tests can require equality, not closeness.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

QMAX = 127


def amax_scale(amax: float) -> float:
    """Activation/weight scale from a calibrated absolute maximum."""
    return max(float(amax), 1e-8) / QMAX


def quantize_np(x: np.ndarray, scale: float) -> np.ndarray:
    q = np.round(np.asarray(x, np.float64) / scale)
    return np.clip(q, -QMAX, QMAX).astype(np.int32)


def half_away_round(x):
    """Round half away from zero, jnp version (f32 semantics)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + jnp.float32(0.5))


def half_away_round_np(x):
    return np.sign(x) * np.floor(np.abs(x) + np.float32(0.5))


@dataclasses.dataclass
class QLayer:
    """One quantized layer as exported to the rust runtime/simulator."""

    name: str
    kind: str  # conv | dwconv | maxpool | avgpool | dense
    k: int
    s: int
    p: int
    relu: bool
    w_q: Optional[np.ndarray]  # int32-valued; layout per kind (see ref.py)
    b_q: Optional[np.ndarray]  # int32 accumulator-scale bias
    m: Optional[float]  # requant multiplier (f32)
    in_shape: tuple
    out_shape: tuple

    def to_json_dict(self) -> dict:
        d = {
            "name": self.name,
            "kind": self.kind,
            "k": self.k,
            "s": self.s,
            "p": self.p,
            "relu": self.relu,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
        }
        if self.w_q is not None:
            d["w_shape"] = list(self.w_q.shape)
            d["w_q"] = [int(v) for v in self.w_q.reshape(-1)]
            d["b_q"] = [int(v) for v in self.b_q.reshape(-1)]
            # Store M as f32 bits so rust reads the identical value.
            d["m"] = float(np.float32(self.m))
        return d


def quantize_dense(name, w, b, s_in, s_out, relu, in_shape, out_shape) -> QLayer:
    """Quantize a dense layer; w (units, feats), b (units,)."""
    w = np.asarray(w, np.float64)
    s_w = amax_scale(np.abs(w).max())
    w_q = quantize_np(w, s_w)
    b_q = np.round(np.asarray(b, np.float64) / (s_in * s_w)).astype(np.int64)
    m = np.float32(s_in * s_w / s_out)
    return QLayer(name, "dense", 0, 1, 0, relu, w_q, b_q, float(m), in_shape, out_shape)


def quantize_conv(name, kind, w, b, s_in, s_out, stride, padding, relu, in_shape, out_shape) -> QLayer:
    """Quantize a conv/dwconv layer; w per ref.py layout."""
    w = np.asarray(w, np.float64)
    s_w = amax_scale(np.abs(w).max())
    w_q = quantize_np(w, s_w)
    b_q = np.round(np.asarray(b, np.float64) / (s_in * s_w)).astype(np.int64)
    m = np.float32(s_in * s_w / s_out)
    k = w.shape[0]
    return QLayer(name, kind, k, stride, padding, relu, w_q, b_q, float(m), in_shape, out_shape)


def requant(acc, m):
    """Int accumulator -> int8 activation (jnp, f32 arithmetic)."""
    y = half_away_round(acc.astype(jnp.float32) * jnp.float32(m))
    return jnp.clip(y, -QMAX, QMAX)
