"""L2: JAX forward passes for the zoo models, float and int8-quantized.

Architectures mirror `rust/src/model/zoo.rs` exactly (same names, shapes,
strides, paddings) — the rust analysis, the pipeline simulator, and these
JAX graphs must agree layer for layer.

Two forward families:

* `forward_float(spec, params, x, use_pallas)` — training/accuracy graph.
  With `use_pallas=True` the convolution/pool/dense hot spots run through
  the L1 Pallas kernels (interpret mode), which is the graph that
  `aot.py` lowers for the rust runtime.
* `forward_int8(qlayers, x_q)` — bit-exact emulation of the quantized
  hardware pipeline (int accumulators + f32 requant), the golden model the
  rust cycle simulator is checked against.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.conv import conv2d_pallas, depthwise_conv2d_pallas
from .kernels.matmul import dense_pallas
from .kernels.pool import maxpool2d_pallas
from .quantize import QMAX, QLayer, requant


@dataclasses.dataclass
class LayerSpec:
    name: str
    kind: str  # conv | dwconv | maxpool | avgpool | dense
    k: int = 0
    s: int = 1
    p: int = 0
    filters: int = 0  # conv/dense output channels
    relu: bool = True


@dataclasses.dataclass
class ModelSpec:
    name: str
    input_hw: int
    input_ch: int
    layers: List[LayerSpec]


def digits_cnn() -> ModelSpec:
    """Mirror of zoo::digits_cnn (E12 end-to-end model)."""
    return ModelSpec(
        "digits_cnn",
        12,
        1,
        [
            LayerSpec("C1", "conv", k=3, s=1, p=1, filters=4),
            LayerSpec("P1", "maxpool", k=2, s=2, relu=False),
            LayerSpec("C2", "conv", k=3, s=1, p=1, filters=8),
            LayerSpec("P2", "maxpool", k=2, s=2, relu=False),
            LayerSpec("F1", "dense", filters=10, relu=False),
        ],
    )


def running_example() -> ModelSpec:
    """Mirror of zoo::running_example (Table V)."""
    return ModelSpec(
        "running_example",
        24,
        1,
        [
            LayerSpec("C1", "conv", k=5, s=1, p=2, filters=8),
            LayerSpec("P1", "maxpool", k=2, s=2, relu=False),
            LayerSpec("C2", "conv", k=5, s=1, p=2, filters=16),
            LayerSpec("P2", "maxpool", k=3, s=3, relu=False),
            LayerSpec("F1", "dense", filters=10, relu=False),
        ],
    )


def jsc_mlp() -> ModelSpec:
    """Mirror of zoo::jsc_mlp (Table X)."""
    return ModelSpec(
        "jsc_mlp",
        1,
        16,
        [
            LayerSpec("fc1", "dense", filters=16, relu=True),
            LayerSpec("fc2", "dense", filters=16, relu=True),
            LayerSpec("fc3", "dense", filters=5, relu=False),
        ],
    )


def layer_shapes(spec: ModelSpec) -> List[Tuple[Tuple[int, int, int], Tuple[int, int, int]]]:
    """(input, output) shapes (h, w, c) per layer; dense flattens."""
    shapes = []
    h, c = spec.input_hw, spec.input_ch
    for l in spec.layers:
        in_shape = (h, h, c)
        if l.kind == "dense":
            in_shape = (1, 1, h * h * c)
            h, c = 1, l.filters
        elif l.kind == "conv":
            h = (h + 2 * l.p - l.k) // l.s + 1
            c = l.filters
        else:  # dwconv / pools keep channels
            h = (h + 2 * l.p - l.k) // l.s + 1
        shapes.append((in_shape, (h, h, c)))
    return shapes


def init_params(spec: ModelSpec, seed: int = 0) -> dict:
    """He-initialised float parameters keyed by layer name."""
    rng = np.random.default_rng(seed)
    params = {}
    shapes = layer_shapes(spec)
    for l, (ins, _) in zip(spec.layers, shapes):
        cin = ins[2]
        if l.kind == "conv":
            fan_in = l.k * l.k * cin
            w = rng.normal(0, np.sqrt(2.0 / fan_in), (l.k, l.k, cin, l.filters))
            params[l.name] = {
                "w": jnp.asarray(w, jnp.float32),
                "b": jnp.zeros((l.filters,), jnp.float32),
            }
        elif l.kind == "dwconv":
            fan_in = l.k * l.k
            w = rng.normal(0, np.sqrt(2.0 / fan_in), (l.k, l.k, cin))
            params[l.name] = {
                "w": jnp.asarray(w, jnp.float32),
                "b": jnp.zeros((cin,), jnp.float32),
            }
        elif l.kind == "dense":
            feats = ins[0] * ins[1] * ins[2]
            w = rng.normal(0, np.sqrt(2.0 / feats), (l.filters, feats))
            params[l.name] = {
                "w": jnp.asarray(w, jnp.float32),
                "b": jnp.zeros((l.filters,), jnp.float32),
            }
    return params


def forward_float(spec: ModelSpec, params: dict, x, use_pallas: bool = False,
                  fake_quant_scales: Optional[dict] = None):
    """Float forward pass for one (H, W, C) input.

    `fake_quant_scales` (from calibration) turns this into the QAT graph:
    activations and weights are passed through the STE fake-quant of
    ref.fake_quant at the given scales.
    """
    fq = fake_quant_scales

    def maybe_fq(t, key):
        if fq is None or key not in fq:
            return t
        return ref.fake_quant(t, fq[key])

    x = maybe_fq(x, "input")
    for l in spec.layers:
        if l.kind in ("conv", "dwconv", "dense"):
            w = maybe_fq(params[l.name]["w"], f"{l.name}/w")
            b = params[l.name]["b"]
        if l.kind == "conv":
            x = (conv2d_pallas if use_pallas else ref.conv2d)(
                x, w, b, stride=l.s, padding=l.p
            )
        elif l.kind == "dwconv":
            x = (depthwise_conv2d_pallas if use_pallas else ref.depthwise_conv2d)(
                x, w, b, stride=l.s, padding=l.p
            )
        elif l.kind == "maxpool":
            x = (maxpool2d_pallas if use_pallas else ref.maxpool2d)(x, l.k, l.s)
        elif l.kind == "avgpool":
            x = ref.avgpool2d(x, l.k, l.s)
        elif l.kind == "dense":
            x = jnp.reshape(x, (-1,))
            x = (dense_pallas if use_pallas else ref.dense)(x, w, b)
        else:
            raise ValueError(l.kind)
        if l.relu:
            x = ref.relu(x)
        x = maybe_fq(x, f"{l.name}/act")
    return x


def _conv_lax(x, w, b, stride, padding):
    """Fused convolution via lax.conv (L2 perf: one HLO convolution op
    instead of H*W per-window dots — see EXPERIMENTS.md §Perf). Exact on
    int8-valued f32 inputs (|acc| < 2^24)."""
    import jax

    y = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    return y + b


def _dwconv_lax(x, w, b, stride, padding):
    import jax

    c = x.shape[-1]
    # (k,k,C) -> grouped conv with feature_group_count = C, HWIO (k,k,1,C).
    wg = w[:, :, None, :] * jnp.ones((1, 1, 1, 1), jnp.float32)
    wg = jnp.transpose(w[:, :, :, None], (0, 1, 3, 2))  # (k,k,1,C)
    y = jax.lax.conv_general_dilated(
        x[None],
        wg,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )[0]
    return y + b


def _maxpool_lax(x, k, s):
    import jax

    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (k, k, 1), (s, s, 1), "VALID"
    )


def forward_int8(qlayers: List[QLayer], x_q):
    """Bit-exact int8 pipeline: x_q int8-valued f32 (H, W, C) or (F,).

    All intermediate activations are int8-valued; accumulators are exact in
    f32 as long as |acc| < 2^24 (asserted by the exporter). Returns the
    final layer's *accumulator-scale* int values (no requant on the last
    layer, matching the paper's 12-bit final output note).

    Uses fused lax convolution/pooling ops (identical integer arithmetic to
    ref.py, verified by tests) so the AOT-lowered HLO stays compact.
    """
    x = x_q
    for i, ql in enumerate(qlayers):
        last = i + 1 == len(qlayers)
        if ql.kind == "conv":
            acc = _conv_lax(
                x,
                jnp.asarray(ql.w_q, jnp.float32),
                jnp.asarray(ql.b_q, jnp.float32),
                ql.s,
                ql.p,
            )
        elif ql.kind == "dwconv":
            acc = _dwconv_lax(
                x,
                jnp.asarray(ql.w_q, jnp.float32),
                jnp.asarray(ql.b_q, jnp.float32),
                ql.s,
                ql.p,
            )
        elif ql.kind == "maxpool":
            x = _maxpool_lax(x, ql.k, ql.s)
            continue
        elif ql.kind == "dense":
            acc = ref.dense(
                jnp.reshape(x, (-1,)),
                jnp.asarray(ql.w_q, jnp.float32),
                jnp.asarray(ql.b_q, jnp.float32),
            )
        else:
            raise ValueError(ql.kind)
        if ql.relu:
            acc = jnp.maximum(acc, 0.0)
        if last:
            return acc
        x = requant(acc, ql.m)
    return x


def calibrate_scales(spec: ModelSpec, params: dict, xs) -> dict:
    """Per-tensor amax calibration over a batch of inputs `xs` (N,H,W,C).

    Returns {key: scale} for input, each weight, and each activation,
    using the float forward pass.
    """
    from .quantize import amax_scale

    amax = {"input": float(np.abs(np.asarray(xs)).max())}
    for l in spec.layers:
        if l.kind in ("conv", "dwconv", "dense"):
            amax[f"{l.name}/w"] = float(np.abs(np.asarray(params[l.name]["w"])).max())

    def record(name, t):
        key = f"{name}/act"
        amax[key] = max(amax.get(key, 0.0), float(np.abs(np.asarray(t)).max()))

    for x in xs:
        t = jnp.asarray(x, jnp.float32)
        for l in spec.layers:
            if l.kind == "conv":
                t = ref.conv2d(t, params[l.name]["w"], params[l.name]["b"], stride=l.s, padding=l.p)
            elif l.kind == "dwconv":
                t = ref.depthwise_conv2d(t, params[l.name]["w"], params[l.name]["b"], stride=l.s, padding=l.p)
            elif l.kind == "maxpool":
                t = ref.maxpool2d(t, l.k, l.s)
            elif l.kind == "avgpool":
                t = ref.avgpool2d(t, l.k, l.s)
            elif l.kind == "dense":
                t = ref.dense(jnp.reshape(t, (-1,)), params[l.name]["w"], params[l.name]["b"])
            if l.relu:
                t = ref.relu(t)
            record(l.name, t)
    return {k: amax_scale(v) for k, v in amax.items()}


def export_qlayers(spec: ModelSpec, params: dict, scales: dict) -> List[QLayer]:
    """Freeze float params + calibration scales into the QLayer pipeline."""
    from .quantize import quantize_conv, quantize_dense

    qlayers = []
    shapes = layer_shapes(spec)
    s_act = scales["input"]
    for l, (ins, outs) in zip(spec.layers, shapes):
        s_out = scales.get(f"{l.name}/act", s_act)
        if l.kind in ("maxpool", "avgpool"):
            # Pooling is scale-preserving (max of int8 is int8).
            qlayers.append(
                QLayer(l.name, l.kind, l.k, l.s, l.p, l.relu, None, None, None, ins, outs)
            )
            continue
        w = np.asarray(params[l.name]["w"])
        b = np.asarray(params[l.name]["b"])
        if l.kind == "dense":
            ql = quantize_dense(l.name, w, b, s_act, s_out, l.relu, ins, outs)
        else:
            ql = quantize_conv(
                l.name, l.kind, w, b, s_act, s_out, l.s, l.p, l.relu, ins, outs
            )
        # Accumulator headroom: f32-exact integers need |acc| < 2^24.
        fan_in = (l.k * l.k * ins[2]) if l.kind != "dense" else ins[0] * ins[1] * ins[2]
        assert QMAX * QMAX * fan_in < 2**24, f"{l.name}: accumulator overflow risk"
        qlayers.append(ql)
        s_act = s_out
    return qlayers
