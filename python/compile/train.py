"""Quantization-aware training (build-time substitute for MQUAT).

Two-phase schedule per model:

1. float pre-training with hand-rolled Adam on the ref-kernel graph;
2. QAT fine-tuning: activations/weights pass through straight-through
   fake-quant at scales calibrated after phase 1 — the same 8-bit
   symmetric fixed-point format the hardware uses.

Training runs in seconds (the models are deliberately small, DESIGN.md §2)
and is invoked once by `make artifacts` via aot.py.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as datasets
from .model import ModelSpec, calibrate_scales, forward_float, init_params


def _loss_fn(spec: ModelSpec, params, xb, yb, scales=None):
    logits = jax.vmap(
        lambda x: forward_float(spec, params, x, use_pallas=False, fake_quant_scales=scales)
    )(xb)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()
    return nll


def _adam_update(params, grads, mstate, vstate, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    for name, layer in params.items():
        new_p[name], new_m[name], new_v[name] = {}, {}, {}
        for key, value in layer.items():
            g = grads[name][key]
            m = b1 * mstate[name][key] + (1 - b1) * g
            v = b2 * vstate[name][key] + (1 - b2) * g * g
            mhat = m / (1 - b1**step)
            vhat = v / (1 - b2**step)
            new_p[name][key] = value - lr * mhat / (jnp.sqrt(vhat) + eps)
            new_m[name][key] = m
            new_v[name][key] = v
    return new_p, new_m, new_v


def _zeros_like_params(params):
    return {n: {k: jnp.zeros_like(v) for k, v in l.items()} for n, l in params.items()}


def train_model(
    spec: ModelSpec,
    xs: np.ndarray,
    ys: np.ndarray,
    float_steps: int = 300,
    qat_steps: int = 150,
    batch: int = 64,
    lr: float = 3e-3,
    seed: int = 0,
) -> Tuple[dict, dict, float]:
    """Train `spec` on (xs, ys); returns (params, scales, qat_accuracy)."""
    rng = np.random.default_rng(seed)
    params = init_params(spec, seed=seed)
    xs_j = jnp.asarray(xs, jnp.float32)
    ys_j = jnp.asarray(ys, jnp.int32)
    n = len(xs)

    loss_float = jax.jit(functools.partial(_loss_fn, spec))
    grad_float = jax.jit(jax.grad(functools.partial(_loss_fn, spec)))

    def run_phase(params, steps, scales, lr):
        m, v = _zeros_like_params(params), _zeros_like_params(params)
        if scales is None:
            gradf = grad_float
        else:
            gradf = jax.jit(
                lambda p, xb, yb: jax.grad(functools.partial(_loss_fn, spec))(
                    p, xb, yb, scales
                )
            )
        for step in range(1, steps + 1):
            idx = rng.integers(0, n, batch)
            g = gradf(params, xs_j[idx], ys_j[idx])
            params, m, v = _adam_update(params, g, m, v, step, lr)
        return params

    params = run_phase(params, float_steps, None, lr)
    # Calibrate on a sample, then QAT fine-tune at lower LR.
    calib_idx = rng.integers(0, n, min(64, n))
    scales = calibrate_scales(spec, params, xs[calib_idx])
    params = run_phase(params, qat_steps, scales, lr * 0.3)
    # Recalibrate activations after QAT (weights moved).
    scales = calibrate_scales(spec, params, xs[calib_idx])

    acc = accuracy(spec, params, xs, ys, scales)
    _ = loss_float  # (kept for interactive debugging)
    return params, scales, acc


def accuracy(spec: ModelSpec, params, xs, ys, scales=None) -> float:
    logits = jax.vmap(
        lambda x: forward_float(spec, params, x, use_pallas=False, fake_quant_scales=scales)
    )(jnp.asarray(xs, jnp.float32))
    pred = np.asarray(jnp.argmax(logits, axis=1))
    return float((pred == ys).mean())


def train_digits(n_train: int = 2000, seed: int = 0):
    from .model import digits_cnn

    xs, ys = datasets.digits(n_train, seed=seed)
    spec = digits_cnn()
    params, scales, acc = train_model(spec, xs, ys, seed=seed)
    return spec, params, scales, acc


def train_jsc(n_train: int = 4000, seed: int = 0):
    from .model import jsc_mlp

    xs, ys = datasets.jsc(n_train, seed=seed)
    spec = jsc_mlp()
    # Dense inputs: reshape to (1,1,16) "pixel" consumed by forward_float.
    xs_img = xs.reshape(-1, 1, 1, 16)
    params, scales, acc = train_model(spec, xs_img, ys, seed=seed)
    return spec, params, scales, acc
