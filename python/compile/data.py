"""Synthetic datasets (build-time substitutes, DESIGN.md §2).

* `digits` — procedural 12x12 digit glyphs with jitter + noise, standing in
  for MNIST-class data for the end-to-end experiment (E12).
* `jsc` — a jet-substructure-like 16-feature 5-class task replacing the
  (unavailable) JSC dataset of [48]: per-class Gaussian clusters with
  correlated features, the same topology/scale the paper's 16-16-5 MLP was
  evaluated on.
"""

from __future__ import annotations

import numpy as np

# 5x4 coarse glyphs for digits 0-9 (1 = ink). Upscaled to 12x12.
_GLYPHS = {
    0: ["1111", "1001", "1001", "1001", "1111"],
    1: ["0010", "0110", "0010", "0010", "0111"],
    2: ["1111", "0001", "1111", "1000", "1111"],
    3: ["1111", "0001", "0111", "0001", "1111"],
    4: ["1001", "1001", "1111", "0001", "0001"],
    5: ["1111", "1000", "1111", "0001", "1111"],
    6: ["1111", "1000", "1111", "1001", "1111"],
    7: ["1111", "0001", "0010", "0100", "0100"],
    8: ["1111", "1001", "1111", "1001", "1111"],
    9: ["1111", "1001", "1111", "0001", "1111"],
}


def _glyph_map(d: int) -> np.ndarray:
    g = np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)
    # Upscale 5x4 -> 10x8 and centre on a 12x12 canvas.
    up = np.kron(g, np.ones((2, 2), np.float32))
    canvas = np.zeros((12, 12), np.float32)
    canvas[1:11, 2:10] = up
    return canvas


def digits(n: int, seed: int = 0):
    """n samples of (12, 12, 1) float images in [0, 1) and labels 0-9."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 12, 12, 1), np.float32)
    ys = rng.integers(0, 10, n)
    for i, y in enumerate(ys):
        img = _glyph_map(int(y))
        # Jitter by up to 1 pixel in each direction.
        dr, dc = rng.integers(-1, 2, 2)
        img = np.roll(np.roll(img, dr, axis=0), dc, axis=1)
        img = img * rng.uniform(0.7, 1.0) + rng.normal(0, 0.1, img.shape)
        xs[i, :, :, 0] = np.clip(img, 0.0, 1.0)
    return xs, ys.astype(np.int32)


def jsc(n: int, seed: int = 0):
    """n samples of 16 jet-substructure-like features and labels 0-4.

    Class means are fixed (derived from a seeded generator) and features
    get a shared random correlation structure, so the task is linearly
    non-trivial but solvable by the 16-16-5 MLP to high accuracy.
    """
    struct = np.random.default_rng(1234)  # fixed structure, independent of `seed`
    means = struct.normal(0, 1.6, (5, 16)).astype(np.float32)
    mix = struct.normal(0, 0.4, (16, 16)).astype(np.float32)
    mix += np.eye(16, dtype=np.float32)
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, 5, n)
    noise = rng.normal(0, 1.0, (n, 16)).astype(np.float32)
    xs = means[ys] + noise @ mix
    # Normalise to a bounded range (hardware-friendly activations).
    xs = np.tanh(xs / 3.0) * 3.0
    return xs.astype(np.float32), ys.astype(np.int32)
