"""L2 model tests: shapes, pallas/ref agreement on full models, int8
pipeline semantics, calibration/export invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data as datasets
from compile.model import (
    calibrate_scales,
    digits_cnn,
    export_qlayers,
    forward_float,
    forward_int8,
    init_params,
    jsc_mlp,
    layer_shapes,
    running_example,
)
from compile.quantize import QMAX, half_away_round_np


def test_layer_shapes_match_rust_zoo():
    # running_example: C1 24x24x8, P1 12x12x8, C2 12x12x16, P2 4x4x16, F1 10.
    outs = [o for (_, o) in layer_shapes(running_example())]
    assert outs == [(24, 24, 8), (12, 12, 8), (12, 12, 16), (4, 4, 16), (1, 1, 10)]
    outs = [o for (_, o) in layer_shapes(digits_cnn())]
    assert outs == [(12, 12, 4), (6, 6, 4), (6, 6, 8), (3, 3, 8), (1, 1, 10)]
    outs = [o for (_, o) in layer_shapes(jsc_mlp())]
    assert outs == [(1, 1, 16), (1, 1, 16), (1, 1, 5)]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_pallas_and_ref_forward_agree(seed):
    spec = digits_cnn()
    params = init_params(spec, seed=seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(12, 12, 1)), jnp.float32)
    a = forward_float(spec, params, x, use_pallas=False)
    b = forward_float(spec, params, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def _quantized_pipeline(spec, n_calib=8, seed=0):
    params = init_params(spec, seed=seed)
    if spec.name == "jsc_mlp":
        xs, _ = datasets.jsc(n_calib, seed=seed)
        xs = xs.reshape(-1, 1, 1, 16)
    else:
        xs, _ = datasets.digits(n_calib, seed=seed)
    scales = calibrate_scales(spec, params, xs)
    return params, scales, export_qlayers(spec, params, scales), xs


def test_int8_pipeline_outputs_are_integers():
    spec = digits_cnn()
    _, scales, qlayers, xs = _quantized_pipeline(spec)
    x_q = np.clip(np.round(xs[0] / scales["input"]), -QMAX, QMAX).astype(np.float32)
    y = np.asarray(forward_int8(qlayers, jnp.asarray(x_q)))
    np.testing.assert_array_equal(y, np.round(y))


def test_int8_pipeline_tracks_float_forward():
    # Dequantized int8 logits must rank classes like the float model on
    # most inputs (quantization fidelity, not exactness).
    spec = digits_cnn()
    params, scales, qlayers, _ = _quantized_pipeline(spec)
    xs, _ = datasets.digits(32, seed=7)
    agree = 0
    for x in xs:
        x = jnp.asarray(x, jnp.float32)
        x_q = jnp.clip(jnp.round(x / scales["input"]), -QMAX, QMAX)
        y_q = forward_int8(qlayers, x_q)
        y_f = forward_float(spec, params, x)
        agree += int(jnp.argmax(y_q) == jnp.argmax(y_f))
    assert agree >= 28, f"only {agree}/32 argmax agreement"


def test_activations_stay_in_int8_range():
    spec = jsc_mlp()
    _, scales, qlayers, xs = _quantized_pipeline(spec)
    x_q = np.clip(np.round(xs[0] / scales["input"]), -QMAX, QMAX).astype(np.float32)
    # Run layer by layer, checking requantized activations.
    from compile.quantize import requant
    from compile.kernels import ref

    x = jnp.asarray(x_q.reshape(-1))
    for ql in qlayers[:-1]:
        acc = ref.dense(x, jnp.asarray(ql.w_q, jnp.float32), jnp.asarray(ql.b_q, jnp.float32))
        if ql.relu:
            acc = jnp.maximum(acc, 0.0)
        x = requant(acc, ql.m)
        assert float(jnp.max(jnp.abs(x))) <= QMAX


def test_half_away_round_semantics():
    xs = np.asarray([-2.5, -1.5, -0.5, 0.5, 1.5, 2.5], np.float32)
    np.testing.assert_array_equal(
        half_away_round_np(xs), [-3.0, -2.0, -1.0, 1.0, 2.0, 3.0]
    )


def test_export_qlayers_structure():
    spec = digits_cnn()
    _, _, qlayers, _ = _quantized_pipeline(spec)
    kinds = [q.kind for q in qlayers]
    assert kinds == ["conv", "maxpool", "conv", "maxpool", "dense"]
    for q in qlayers:
        if q.w_q is not None:
            assert np.abs(q.w_q).max() <= QMAX
            d = q.to_json_dict()
            assert "m" in d and "w_q" in d and "b_q" in d


def test_jsc_dataset_is_learnably_separable():
    # Nearest-class-mean on the synthetic JSC features must beat chance by
    # a wide margin (sanity of the dataset substitution).
    xs, ys = datasets.jsc(2000, seed=1)
    means = np.stack([xs[ys == c].mean(axis=0) for c in range(5)])
    pred = np.argmin(((xs[:, None, :] - means[None]) ** 2).sum(-1), axis=1)
    assert (pred == ys).mean() > 0.8


def test_digits_dataset_labels_balanced():
    _, ys = datasets.digits(1000, seed=0)
    counts = np.bincount(ys, minlength=10)
    assert counts.min() > 50
