"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/strides/paddings; assert_allclose against ref.
This is the core correctness signal for the compute the rust runtime
eventually executes via the AOT HLO.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv import conv2d_pallas, depthwise_conv2d_pallas
from compile.kernels.matmul import dense_pallas
from compile.kernels.pool import maxpool2d_pallas

RTOL = 1e-4
ATOL = 1e-4


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    f=st.integers(4, 10),
    k=st.integers(1, 4),
    cin=st.integers(1, 4),
    cout=st.integers(1, 5),
    stride=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_pallas_matches_ref(f, k, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, f, f, cin)
    w = rand(rng, k, k, cin, cout)
    b = rand(rng, cout)
    for padding in range(0, (k - 1) // 2 + 1):
        if f + 2 * padding < k:
            continue
        got = conv2d_pallas(x, w, b, stride=stride, padding=padding)
        want = ref.conv2d(x, w, b, stride=stride, padding=padding)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    f=st.integers(4, 10),
    k=st.integers(1, 3),
    c=st.integers(1, 6),
    stride=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_pallas_matches_ref(f, k, c, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, f, f, c)
    w = rand(rng, k, k, c)
    b = rand(rng, c)
    padding = (k - 1) // 2
    got = depthwise_conv2d_pallas(x, w, b, stride=stride, padding=padding)
    want = ref.depthwise_conv2d(x, w, b, stride=stride, padding=padding)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    f=st.integers(4, 12),
    k=st.integers(2, 3),
    c=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_pallas_matches_ref(f, k, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, f, f, c)
    for stride in (k,):  # pooling stride = k, the paper's configuration
        got = maxpool2d_pallas(x, k, stride)
        want = ref.maxpool2d(x, k, stride)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(
    feats=st.integers(1, 64),
    units_blocks=st.tuples(st.integers(1, 8), st.integers(1, 4)),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_pallas_matches_ref(feats, units_blocks, seed):
    h, blocks = units_blocks
    units = h * blocks
    rng = np.random.default_rng(seed)
    x = rand(rng, feats)
    w = rand(rng, units, feats)
    b = rand(rng, units)
    got = dense_pallas(x, w, b, block=h)
    want = ref.dense(x, w, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv_padding_matches_running_example_geometry():
    # C1 of the running example: 24x24x1, k=5, p=2 -> 24x24x8.
    rng = np.random.default_rng(0)
    x = rand(rng, 24, 24, 1)
    w = rand(rng, 5, 5, 1, 8)
    b = rand(rng, 8)
    y = conv2d_pallas(x, w, b, stride=1, padding=2)
    assert y.shape == (24, 24, 8)
    np.testing.assert_allclose(
        y, ref.conv2d(x, w, b, stride=1, padding=2), rtol=RTOL, atol=ATOL
    )


def test_dense_block_must_divide():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        dense_pallas(rand(rng, 4), rand(rng, 10, 4), rand(rng, 10), block=3)


def test_quant_roundtrip_int_grid():
    # Integers on the int8 grid survive quantize/dequantize exactly.
    s = ref.quant_scale(127.0)  # scale 1.0
    xs = jnp.asarray([-127.0, -1.0, 0.0, 1.0, 126.0])
    np.testing.assert_array_equal(ref.dequantize(ref.quantize(xs, s), s), xs)


def test_fake_quant_gradient_is_straight_through():
    import jax

    s = ref.quant_scale(1.0)
    g = jax.grad(lambda x: ref.fake_quant(x, s).sum())(jnp.asarray([0.3, -0.7]))
    np.testing.assert_allclose(g, [1.0, 1.0])
