"""AOT path tests: HLO text generation, quantization export semantics,
and (when artifacts exist) manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as datasets
from compile.aot import lower_int8, to_hlo_text
from compile.model import (
    calibrate_scales,
    digits_cnn,
    export_qlayers,
    forward_int8,
    init_params,
    jsc_mlp,
)
from compile.quantize import QMAX, amax_scale, half_away_round_np, quantize_np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _qlayers(spec, seed=0):
    params = init_params(spec, seed=seed)
    if spec.name == "jsc_mlp":
        xs, _ = datasets.jsc(8, seed=seed)
        xs = xs.reshape(-1, 1, 1, 16)
    else:
        xs, _ = datasets.digits(8, seed=seed)
    scales = calibrate_scales(spec, params, xs)
    return export_qlayers(spec, params, scales), scales


def test_hlo_text_contains_full_constants():
    qlayers, _ = _qlayers(jsc_mlp())
    text = to_hlo_text(lower_int8(qlayers, (1, 1, 16)))
    assert "{...}" not in text
    assert "ENTRY" in text
    # Weight matrices must appear as f32 constants of the right shape.
    assert "f32[16,16]" in text
    # No jax metadata attributes the old parser would reject.
    assert "source_end_line" not in text


def test_hlo_text_roundtrips_through_parser():
    from jax._src.lib import xla_client as xc

    qlayers, _ = _qlayers(jsc_mlp())
    text = to_hlo_text(lower_int8(qlayers, (1, 1, 16)))
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_lowered_int8_executes_like_eager():
    qlayers, scales = _qlayers(jsc_mlp())
    xs, _ = datasets.jsc(4, seed=77)
    for x in xs:
        x_q = np.clip(np.round(x / scales["input"]), -QMAX, QMAX).astype(np.float32)
        eager = np.asarray(forward_int8(qlayers, jnp.asarray(x_q.reshape(1, 1, 16))))
        lowered = lower_int8(qlayers, (1, 1, 16))
        compiled = jax.jit(lambda v: forward_int8(qlayers, v))
        got = np.asarray(compiled(jnp.asarray(x_q.reshape(1, 1, 16))))
        np.testing.assert_array_equal(got, eager)
        del lowered


@settings(max_examples=50, deadline=None)
@given(
    amax=st.floats(1e-3, 1e3, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_np_bounds_and_grid(amax, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, amax, 64)
    s = amax_scale(np.abs(x).max())
    q = quantize_np(x, s)
    assert q.max() <= QMAX and q.min() >= -QMAX
    # Dequantized error bounded by half a step.
    err = np.abs(q * s - np.clip(x, -QMAX * s, QMAX * s))
    assert err.max() <= s / 2 + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=32))
def test_half_away_round_matches_definition(xs):
    xs = np.asarray(xs, np.float32)
    got = half_away_round_np(xs)
    want = np.sign(xs) * np.floor(np.abs(xs) + np.float32(0.5))
    np.testing.assert_array_equal(got, want)


def test_digits_export_accumulator_headroom():
    qlayers, _ = _qlayers(digits_cnn())
    for ql in qlayers:
        if ql.w_q is None:
            continue
        if ql.kind == "dense":
            fan_in = np.prod(ql.in_shape)
        else:
            fan_in = ql.k * ql.k * ql.in_shape[2]
        assert QMAX * QMAX * fan_in < 2**24


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "meta.json")),
    reason="artifacts not built",
)
def test_artifact_manifest_consistent():
    with open(os.path.join(ARTIFACTS, "meta.json")) as f:
        meta = json.load(f)
    for name, entry in meta["models"].items():
        assert entry["qat_accuracy"] > 0.9, name
        wpath = os.path.join(ARTIFACTS, entry["weights"])
        with open(wpath) as f:
            w = json.load(f)
        assert w["name"] == name
        assert len(w["test_vectors"]) >= 8
        # Weights within int8.
        for layer in w["layers"]:
            if "w_q" in layer:
                assert max(abs(v) for v in layer["w_q"]) <= QMAX


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "meta.json")),
    reason="artifacts not built",
)
def test_artifact_vectors_replay_in_python():
    """The exported test vectors must replay through forward_int8 when the
    quantized layers are reloaded from JSON (guards exporter drift)."""
    from compile.quantize import QLayer

    with open(os.path.join(ARTIFACTS, "weights", "jsc.json")) as f:
        w = json.load(f)
    qlayers = []
    for l in w["layers"]:
        qlayers.append(
            QLayer(
                l["name"],
                l["kind"],
                l["k"],
                l["s"],
                l["p"],
                l["relu"],
                np.asarray(l["w_q"]).reshape(l["w_shape"]) if "w_q" in l else None,
                np.asarray(l["b_q"]) if "b_q" in l else None,
                l.get("m"),
                tuple(l["in_shape"]),
                tuple(l["out_shape"]),
            )
        )
    for tv in w["test_vectors"][:4]:
        x_q = np.asarray(tv["x_q"], np.float32).reshape(w["input_shape"])
        y = np.asarray(forward_int8(qlayers, jnp.asarray(x_q))).reshape(-1)
        np.testing.assert_array_equal(y, np.asarray(tv["y"], np.float32))
