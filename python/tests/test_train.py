"""Training-loop smoke tests (short budgets so pytest stays fast)."""

import numpy as np

from compile import data as datasets
from compile.model import digits_cnn, jsc_mlp
from compile.train import accuracy, train_model


def test_jsc_short_training_beats_chance_by_far():
    xs, ys = datasets.jsc(800, seed=3)
    spec = jsc_mlp()
    params, scales, acc = train_model(
        spec, xs.reshape(-1, 1, 1, 16), ys, float_steps=120, qat_steps=40, seed=3
    )
    assert acc > 0.85, f"QAT accuracy {acc}"
    # Float accuracy (no fake quant) should be at least as good - small tol.
    facc = accuracy(spec, params, xs.reshape(-1, 1, 1, 16), ys, scales=None)
    assert facc > 0.85
    assert "input" in scales and "fc1/w" in scales


def test_digits_short_training_learns_glyphs():
    xs, ys = datasets.digits(300, seed=5)
    spec = digits_cnn()
    _, _, acc = train_model(spec, xs, ys, float_steps=80, qat_steps=30, batch=32, seed=5)
    # Ten glyph classes with jitter/noise: well above the 10% chance level
    # even with a tiny budget.
    assert acc > 0.5, f"QAT accuracy {acc}"


def test_qat_preserves_calibration_keys():
    xs, ys = datasets.jsc(400, seed=9)
    spec = jsc_mlp()
    _, scales, _ = train_model(
        spec, xs.reshape(-1, 1, 1, 16), ys, float_steps=50, qat_steps=20, seed=9
    )
    for l in spec.layers:
        assert f"{l.name}/w" in scales
        assert f"{l.name}/act" in scales
        assert scales[f"{l.name}/w"] > 0


def test_datasets_deterministic_by_seed():
    a1, y1 = datasets.jsc(64, seed=42)
    a2, y2 = datasets.jsc(64, seed=42)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(y1, y2)
    b1, _ = datasets.digits(16, seed=7)
    b2, _ = datasets.digits(16, seed=7)
    np.testing.assert_array_equal(b1, b2)
    b3, _ = datasets.digits(16, seed=8)
    assert not np.array_equal(b1, b3)
