//! Quickstart: define a CNN, run the data-rate analysis, and get the
//! continuous-flow unit plan plus resource/FPGA estimates.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use cnn_flow::complexity::{model_cost, parallel::fully_parallel_cost, CostOpts};
use cnn_flow::flow::{analyze, plan_all};
use cnn_flow::fpga::{estimate_model, EstimatorOpts};
use cnn_flow::model::{Layer, Model};
use cnn_flow::util::paper_count;

fn main() {
    // 1. Describe a network (or load one from JSON / take one from the zoo).
    let mut model = Model::new("my_tiny_cnn", 28, 1);
    model.push(Layer::conv("C1", 3, 1, 1, 8));
    model.push(Layer::maxpool("P1", 2, 2));
    model.push(Layer::conv("C2", 3, 1, 1, 16));
    model.push(Layer::maxpool("P2", 2, 2));
    model.push(Layer::dense("F1", 10));

    // 2. Propagate data rates (Eq. 8) at the full input rate r0 = d0.
    let analysis = analyze(&model, None).expect("shapes check out");
    println!("data rates through {}:", model.name);
    for l in &analysis.layers {
        println!(
            "  {:<4} r_in={:<5} r_out={:<5} ({}x{}x{} -> {}x{}x{})",
            l.shaped.layer.name,
            l.r_in.paper(),
            l.r_out.paper(),
            l.shaped.input.f,
            l.shaped.input.f,
            l.shaped.input.d,
            l.shaped.output.f,
            l.shaped.output.f,
            l.shaped.output.d,
        );
    }

    // 3. Plan interleaving + units (Eqs. 12-22) and cost them (Eqs. 23-37).
    let plans = plan_all(&analysis);
    println!("\nunit plan:");
    for p in &plans {
        println!(
            "  {:<4} {:>3} units, C={:<3} {}",
            p.rated.shaped.layer.name,
            p.plan.unit_count(),
            p.plan.configs(),
            if p.plan.stalled() { "(stalled)" } else { "" },
        );
    }

    let ours = model_cost(&plans, CostOpts::FULL).total;
    let reference = fully_parallel_cost(&analysis, CostOpts::FULL).total;
    println!(
        "\ncontinuous flow: {} adders, {} multipliers, {} registers",
        paper_count(ours.adders),
        paper_count(ours.multipliers),
        paper_count(ours.registers)
    );
    println!(
        "fully parallel : {} adders, {} multipliers, {} registers",
        paper_count(reference.adders),
        paper_count(reference.multipliers),
        paper_count(reference.registers)
    );
    println!(
        "arithmetic saved: {:.1}x",
        reference.multipliers as f64 / ours.multipliers as f64
    );

    // 4. FPGA estimate (the paper's Vivado substitute).
    let est = estimate_model(&plans, EstimatorOpts::default(), None);
    println!(
        "\nFPGA estimate: {} LUT, {} FF, {} DSP, {:.1} BRAM36 @ {:.0} MHz, {:.1} W",
        est.lut, est.ff, est.dsp, est.bram36, est.fmax_mhz, est.power_w
    );
}
