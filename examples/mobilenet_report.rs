//! Reproduce the model-scale evaluation: Table VIII (fully parallel vs
//! continuous flow for MobileNetV1 x4 alphas + ResNet18) and Table IX
//! (MobileNetV1 synthesis comparison via the estimator).
//!
//! ```bash
//! cargo run --release --offline --example mobilenet_report
//! ```

use cnn_flow::flow::{analyze, plan_all};
use cnn_flow::fpga::{estimate_model, timing::timing_analytic, EstimatorOpts, XCVU37P};
use cnn_flow::model::zoo;
use cnn_flow::report::synthesis;
use cnn_flow::report::tables;

fn main() {
    println!("{}", tables::table8());
    println!("{}", synthesis::table9());

    // Per-alpha deployment check: does each MobileNet variant fit the
    // paper's part, and at what projected FPS?
    println!("== MobileNetV1 deployment sweep (estimator) ==");
    for alpha in [25, 50, 75, 100] {
        let model = zoo::mobilenet_v1(alpha);
        let analysis = analyze(&model, None).unwrap();
        let plans = plan_all(&analysis);
        let est = estimate_model(&plans, EstimatorOpts::default(), None);
        let t = timing_analytic(&analysis, 1);
        let fps = est.fmax_mhz * 1e6 / t.cycles_per_frame;
        println!(
            "alpha={:<4} {:>8} LUT ({:>4.1}%), {:>5} DSP, {:>6.1} BRAM36, {:>4.0} MHz, {:>7.0} FPS, fits={}",
            alpha as f64 / 100.0,
            est.lut,
            XCVU37P.lut_util(est.lut) * 100.0,
            est.dsp,
            est.bram36,
            est.fmax_mhz,
            fps,
            XCVU37P.fits(est.lut, est.ff, est.dsp, est.bram36),
        );
    }
}
