//! E12 — the end-to-end driver (DESIGN.md §5): load the QAT-trained digits
//! CNN artifact, serve a stream of batched inference requests through the
//! sharded continuous-flow coordinator, cross-check sampled answers
//! against the AOT-compiled JAX int8 golden model via PJRT, and report
//! accuracy, latency and throughput (wall-clock and projected hardware).
//!
//! Without artifacts the example falls back to the deterministic synthetic
//! fixture ([`QModel::synthetic`]) and verifies every response against the
//! single-`PipelineSim` golden path instead — so it always runs.
//!
//! # Serve CLI flags (example and `cnn-flow serve`)
//!
//! | flag | default | meaning |
//! |------|---------|---------|
//! | `--workers N` | 2 (CLI: 1) | worker shards; each owns a pipeline replica, aggregate throughput scales with N |
//! | `--requests N` | 512 (CLI: 256) | total requests issued by the 4 client threads |
//! | `--batch N` | 16 | max frames per contiguous continuous-flow group |
//! | `--queue-depth N` | 256 | bounded queue depth per shard (backpressure threshold) |
//! | `--verify-every N` | 4 (CLI: 8) | per-shard golden-verify sampling period (0 = off; forced off on the synthetic path, which has no PJRT golden model) |
//! | `--engine compiled\|interp` | compiled | shard execution engine: the lowered `CompiledPipeline` + closed-form `SchedulePrediction` (default), or the fused cycle-exact interpreter oracle (which live-checks the prediction; see `cycle_divergence`) |
//! | `--synthetic` | off | CLI only: serve the artifact-free synthetic fixture |
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_stream -- --workers 4
//! ```

use std::sync::Arc;
use std::time::Instant;

use cnn_flow::coordinator::{EngineKind, Server, ServerConfig};
use cnn_flow::quant::{quantize, QModel};
use cnn_flow::runtime::artifacts_dir;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::Rng;

/// Regenerate the synthetic digit glyphs (the same procedural dataset as
/// `python/compile/data.py`) so the serving demo classifies real held-out
/// samples, not just the exporter's vectors.
fn make_digit(rng: &mut Rng, label: usize) -> Vec<f32> {
    const GLYPHS: [[&str; 5]; 10] = [
        ["1111", "1001", "1001", "1001", "1111"],
        ["0010", "0110", "0010", "0010", "0111"],
        ["1111", "0001", "1111", "1000", "1111"],
        ["1111", "0001", "0111", "0001", "1111"],
        ["1001", "1001", "1111", "0001", "0001"],
        ["1111", "1000", "1111", "0001", "1111"],
        ["1111", "1000", "1111", "1001", "1111"],
        ["1111", "0001", "0010", "0100", "0100"],
        ["1111", "1001", "1111", "1001", "1111"],
        ["1111", "1001", "1111", "0001", "1111"],
    ];
    // 5x4 glyph -> 10x8 upscale -> centred on 12x12, jitter + noise.
    let mut canvas = vec![0f32; 144];
    let (dr, dc) = (
        rng.range(0, 2) as isize - 1,
        rng.range(0, 2) as isize - 1,
    );
    let bright = 0.7 + 0.3 * rng.f64() as f32;
    for gr in 0..5 {
        for gc in 0..4 {
            if GLYPHS[label][gr].as_bytes()[gc] == b'1' {
                for ur in 0..2 {
                    for uc in 0..2 {
                        let r = 1 + gr * 2 + ur;
                        let c = 2 + gc * 2 + uc;
                        let rr = r as isize + dr;
                        let cc = c as isize + dc;
                        if (0..12).contains(&rr) && (0..12).contains(&cc) {
                            canvas[(rr * 12 + cc) as usize] = bright;
                        }
                    }
                }
            }
        }
    }
    for v in &mut canvas {
        *v = (*v + 0.1 * rng.normal() as f32).clamp(0.0, 1.0);
    }
    canvas
}

fn flag(args: &[String], name: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn engine_flag(args: &[String]) -> EngineKind {
    match args
        .iter()
        .position(|a| a == "--engine")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
    {
        Some("interp") | Some("interpreter") => EngineKind::Interpreter,
        _ => EngineKind::Compiled,
    }
}

fn shard_report(server: &Server) {
    println!("\nper-shard serving stats:");
    for s in server.shard_metrics() {
        println!(
            "  shard {} completed {:>5}  batches {:>4}  busy {:>9} cycles  p50 {:?}  p99 {:?}",
            s.shard, s.completed, s.batches, s.busy_cycles, s.p50, s.p99
        );
    }
}

/// Artifact-free path: serve the synthetic fixture and check every answer
/// bit-for-bit against the single-pipeline golden sim.
fn serve_synthetic(opts: &ServeOpts) {
    println!("artifacts not built: serving the synthetic fixture instead");
    let n_requests = opts.requests;
    let qm = QModel::synthetic(12, 8, 10, 0xE12);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let config = ServerConfig {
        workers: opts.workers,
        max_batch: opts.batch,
        queue_depth: opts.queue_depth,
        verify_every: 0, // no PJRT golden model on the synthetic path
        engine: opts.engine,
        ..Default::default()
    };
    let clock_hz = config.clock_hz;
    let lowered = if golden.compiled.is_narrow() {
        "narrow/i32"
    } else {
        "wide/i64"
    };
    println!(
        "engine: {:?} (lowered {lowered}, steady {} cycles/frame predicted)",
        opts.engine, golden.predicted.steady_cycles_per_frame,
    );
    let server = Arc::new(Server::start_prelowered(golden.clone(), config, None).unwrap());
    let started = Instant::now();
    let mut handles = Vec::new();
    for client in 0..4usize {
        let s = Arc::clone(&server);
        let golden = golden.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xE12 + client as u64);
            let (mut served, mut exact) = (0usize, 0usize);
            for _ in 0..n_requests / 4 {
                let x: Vec<i64> = (0..144).map(|_| rng.int8() as i64).collect();
                let expect = golden.run(&[x.clone()]).unwrap().outputs[0].clone();
                match s.infer(x) {
                    Ok(resp) => {
                        served += 1;
                        if resp.logits == expect {
                            exact += 1;
                        }
                    }
                    Err(_) => {} // backpressure
                }
            }
            (served, exact)
        }));
    }
    let (mut served, mut exact) = (0usize, 0usize);
    for h in handles {
        let (s, e) = h.join().unwrap();
        served += s;
        exact += e;
    }
    let wall = started.elapsed();
    let mut server = Arc::try_unwrap(server).ok().expect("clients joined");
    server.drain();
    let m = server.metrics();
    println!(
        "served {served}/{n_requests} in {wall:?}; {exact}/{served} bit-identical to golden sim"
    );
    println!(
        "coordinator: {} shard(s), mean batch {:.1}, p50 {:?}, p99 {:?}, aggregate {:.2} MInf/s at {:.0} MHz",
        m.workers,
        m.mean_batch,
        m.p50,
        m.p99,
        m.aggregate_fps / 1e6,
        clock_hz / 1e6,
    );
    println!(
        "cycle model: {} predicted cycles, {} interpreter-simulated, {} divergent groups",
        m.predicted_cycles, m.simulated_cycles, m.cycle_divergence
    );
    shard_report(&server);
    assert_eq!(exact, served, "sharded serving diverged from the golden sim");
    assert_eq!(m.cycle_divergence, 0, "schedule prediction diverged");
    println!("OK (synthetic)");
}

struct ServeOpts {
    workers: usize,
    requests: usize,
    batch: usize,
    queue_depth: usize,
    verify_every: usize,
    engine: EngineKind,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ServeOpts {
        workers: flag(&args, "--workers", 2),
        requests: flag(&args, "--requests", 512),
        batch: flag(&args, "--batch", 16),
        queue_depth: flag(&args, "--queue-depth", 256),
        verify_every: flag(&args, "--verify-every", 4),
        engine: engine_flag(&args),
    };
    let n_requests = opts.requests;

    // --- load the trained artifact (or fall back to the fixture) --------
    let path = artifacts_dir().join("weights/digits.json");
    let qm = match QModel::load(&path) {
        Ok(q) => q,
        Err(e) => {
            // Surface the reason (a *corrupt* artifact deserves a
            // diagnosis, not a silent fallback), then run artifact-free.
            eprintln!("cannot serve the digits artifact: {e}");
            serve_synthetic(&opts);
            return;
        }
    };
    println!(
        "loaded digits CNN: {} layers, QAT accuracy {:.2}%, input scale {}",
        qm.layers.len(),
        qm.qat_accuracy * 100.0,
        qm.input_scale
    );

    // --- hardware projection from the cycle-accurate pipeline ----------
    let sim = PipelineSim::new(qm.clone(), None).unwrap();
    let warm: Vec<Vec<i64>> = qm.test_vectors.iter().map(|tv| tv.x_q.clone()).collect();
    let proj = sim.run(&warm).unwrap();
    println!("\nper-layer utilisation (continuous flow, back-to-back frames):");
    for s in &proj.stats {
        println!(
            "  {:<4} {:>3} {}s  utilization {:>5.1}%",
            s.name,
            s.units,
            s.unit_kind,
            s.utilization * 100.0
        );
    }
    println!(
        "frame latency {} cycles; steady-state {:.1} cycles/frame",
        proj.first_frame_latency, proj.cycles_per_frame
    );

    // --- serve a stream -------------------------------------------------
    let config = ServerConfig {
        workers: opts.workers,
        max_batch: opts.batch,
        queue_depth: opts.queue_depth,
        verify_every: opts.verify_every,
        engine: opts.engine,
        ..Default::default()
    };
    let clock_hz = config.clock_hz;
    let lowered = if sim.compiled.is_narrow() {
        "narrow/i32"
    } else {
        "wide/i64"
    };
    println!(
        "engine: {:?} (lowered {lowered}, steady {} cycles/frame predicted)",
        opts.engine, sim.predicted.steady_cycles_per_frame,
    );
    let server = Arc::new(
        Server::start_prelowered(sim.clone(), config, Some("digits".to_string())).unwrap(),
    );
    let n_clients = 4usize;
    let started = Instant::now();
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let s = Arc::clone(&server);
        let scale = qm.input_scale;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xE12 + client as u64);
            let mut correct = 0usize;
            let mut served = 0usize;
            for _ in 0..n_requests / n_clients {
                let label = rng.range(0, 9);
                let img = make_digit(&mut rng, label);
                let x_q: Vec<i64> = img.iter().map(|&v| quantize(v, scale)).collect();
                match s.infer(x_q) {
                    Ok(resp) => {
                        served += 1;
                        if resp.argmax == label {
                            correct += 1;
                        }
                    }
                    Err(_) => {} // backpressure
                }
            }
            (served, correct)
        }));
    }
    let (mut served, mut correct) = (0usize, 0usize);
    for h in handles {
        let (s, c) = h.join().unwrap();
        served += s;
        correct += c;
    }
    let wall = started.elapsed();
    // Graceful drain (replaces the old sleep-and-hope): joins the shard
    // workers and the verifier after its sampling queue empties.
    let mut server = Arc::try_unwrap(server).ok().expect("clients joined");
    server.drain();
    let m = server.metrics();

    // --- report ----------------------------------------------------------
    println!("\n== E12 end-to-end results ==");
    println!(
        "served {served}/{n_requests} requests in {wall:?} ({:.0} req/s wall)",
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "held-out accuracy: {:.1}% ({correct}/{served})",
        correct as f64 / served as f64 * 100.0
    );
    println!(
        "coordinator: {} shard(s), mean batch {:.1}, mean service {:?} (p50 {:?}, p95 {:?}, p99 {:?})",
        m.workers, m.mean_batch, m.mean_service, m.p50, m.p95, m.p99
    );
    println!(
        "projected hardware: {:.2} MInf/s per pipeline, {:.2} MInf/s aggregate at {:.0} MHz ({:.1} us/frame latency)",
        m.projected_fps / 1e6,
        m.aggregate_fps / 1e6,
        clock_hz / 1e6,
        proj.first_frame_latency as f64 / clock_hz * 1e6,
    );
    shard_report(&server);
    println!(
        "cycle model: {} predicted cycles, {} interpreter-simulated, {} divergent groups",
        m.predicted_cycles, m.simulated_cycles, m.cycle_divergence
    );
    println!(
        "golden cross-check (PJRT): {} verified, {} mismatches",
        m.verified, m.mismatches
    );
    assert_eq!(m.mismatches, 0, "cycle sim diverged from the golden model");
    assert_eq!(m.cycle_divergence, 0, "schedule prediction diverged");
    assert!(
        correct as f64 / served as f64 > 0.9,
        "accuracy regression on held-out digits"
    );
    println!("OK");
}
