//! E12 — the end-to-end driver (DESIGN.md §5): load the QAT-trained digits
//! CNN artifact, serve a stream of batched inference requests through the
//! continuous-flow coordinator, cross-check sampled answers against the
//! AOT-compiled JAX int8 golden model via PJRT, and report accuracy,
//! latency and throughput (wall-clock and projected hardware).
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_stream
//! ```

use std::sync::Arc;
use std::time::Instant;

use cnn_flow::coordinator::{Server, ServerConfig};
use cnn_flow::quant::{quantize, QModel};
use cnn_flow::runtime::artifacts_dir;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::Rng;

/// Regenerate the synthetic digit glyphs (the same procedural dataset as
/// `python/compile/data.py`) so the serving demo classifies real held-out
/// samples, not just the exporter's vectors.
fn make_digit(rng: &mut Rng, label: usize) -> Vec<f32> {
    const GLYPHS: [[&str; 5]; 10] = [
        ["1111", "1001", "1001", "1001", "1111"],
        ["0010", "0110", "0010", "0010", "0111"],
        ["1111", "0001", "1111", "1000", "1111"],
        ["1111", "0001", "0111", "0001", "1111"],
        ["1001", "1001", "1111", "0001", "0001"],
        ["1111", "1000", "1111", "0001", "1111"],
        ["1111", "1000", "1111", "1001", "1111"],
        ["1111", "0001", "0010", "0100", "0100"],
        ["1111", "1001", "1111", "1001", "1111"],
        ["1111", "1001", "1111", "0001", "1111"],
    ];
    // 5x4 glyph -> 10x8 upscale -> centred on 12x12, jitter + noise.
    let mut canvas = vec![0f32; 144];
    let (dr, dc) = (
        rng.range(0, 2) as isize - 1,
        rng.range(0, 2) as isize - 1,
    );
    let bright = 0.7 + 0.3 * rng.f64() as f32;
    for gr in 0..5 {
        for gc in 0..4 {
            if GLYPHS[label][gr].as_bytes()[gc] == b'1' {
                for ur in 0..2 {
                    for uc in 0..2 {
                        let r = 1 + gr * 2 + ur;
                        let c = 2 + gc * 2 + uc;
                        let rr = r as isize + dr;
                        let cc = c as isize + dc;
                        if (0..12).contains(&rr) && (0..12).contains(&cc) {
                            canvas[(rr * 12 + cc) as usize] = bright;
                        }
                    }
                }
            }
        }
    }
    for v in &mut canvas {
        *v = (*v + 0.1 * rng.normal() as f32).clamp(0.0, 1.0);
    }
    canvas
}

fn main() {
    // --- load the trained artifact -------------------------------------
    let path = artifacts_dir().join("weights/digits.json");
    let qm = match QModel::load(&path) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "loaded digits CNN: {} layers, QAT accuracy {:.2}%, input scale {}",
        qm.layers.len(),
        qm.qat_accuracy * 100.0,
        qm.input_scale
    );

    // --- hardware projection from the cycle-accurate pipeline ----------
    let sim = PipelineSim::new(qm.clone(), None).unwrap();
    let warm: Vec<Vec<i64>> = qm.test_vectors.iter().map(|tv| tv.x_q.clone()).collect();
    let proj = sim.run(&warm).unwrap();
    println!("\nper-layer utilisation (continuous flow, back-to-back frames):");
    for s in &proj.stats {
        println!(
            "  {:<4} {:>3} {}s  utilization {:>5.1}%",
            s.name,
            s.units,
            s.unit_kind,
            s.utilization * 100.0
        );
    }
    println!(
        "frame latency {} cycles; steady-state {:.1} cycles/frame",
        proj.first_frame_latency, proj.cycles_per_frame
    );

    // --- serve a stream -------------------------------------------------
    let config = ServerConfig {
        batch: 16,
        verify_every: 4,
        ..Default::default()
    };
    let clock_hz = config.clock_hz;
    let server = Arc::new(
        Server::start(qm.clone(), config, Some("digits".to_string())).unwrap(),
    );
    let n_requests = 512usize;
    let n_clients = 4usize;
    let started = Instant::now();
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let s = Arc::clone(&server);
        let scale = qm.input_scale;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xE12 + client as u64);
            let mut correct = 0usize;
            let mut served = 0usize;
            for _ in 0..n_requests / n_clients {
                let label = rng.range(0, 9);
                let img = make_digit(&mut rng, label);
                let x_q: Vec<i64> = img.iter().map(|&v| quantize(v, scale)).collect();
                match s.infer(x_q) {
                    Ok(resp) => {
                        served += 1;
                        if resp.argmax == label {
                            correct += 1;
                        }
                    }
                    Err(_) => {} // backpressure
                }
            }
            (served, correct)
        }));
    }
    let (mut served, mut correct) = (0usize, 0usize);
    for h in handles {
        let (s, c) = h.join().unwrap();
        served += s;
        correct += c;
    }
    let wall = started.elapsed();
    std::thread::sleep(std::time::Duration::from_millis(300));
    let m = Arc::try_unwrap(server)
        .map(|s| s.shutdown())
        .unwrap_or_else(|s| s.metrics());

    // --- report ----------------------------------------------------------
    println!("\n== E12 end-to-end results ==");
    println!(
        "served {served}/{n_requests} requests in {wall:?} ({:.0} req/s wall)",
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "held-out accuracy: {:.1}% ({correct}/{served})",
        correct as f64 / served as f64 * 100.0
    );
    println!(
        "coordinator: mean batch {:.1}, mean service {:?}",
        m.mean_batch, m.mean_service
    );
    println!(
        "projected hardware: {:.2} MInf/s at {:.0} MHz ({:.1} us/frame latency)",
        m.projected_fps / 1e6,
        clock_hz / 1e6,
        proj.first_frame_latency as f64 / clock_hz * 1e6,
    );
    println!(
        "golden cross-check (PJRT): {} verified, {} mismatches",
        m.verified, m.mismatches
    );
    assert_eq!(m.mismatches, 0, "cycle sim diverged from the golden model");
    assert!(
        correct as f64 / served as f64 > 0.9,
        "accuracy regression on held-out digits"
    );
    println!("OK");
}
