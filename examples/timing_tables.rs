//! Reproduce the paper's cycle-by-cycle timing tables (Tables I-IV) from
//! the structural register-level simulators, with every labelled cell
//! verified against the convolution/dense oracles.
//!
//! ```bash
//! cargo run --release --offline --example timing_tables
//! ```

use cnn_flow::report::timing;
use cnn_flow::sim::trace::{trace_kpu, verify_kpu_trace, KpuTraceCfg};

fn main() {
    println!("{}", timing::table1());
    println!("{}", timing::table2());
    println!("{}", timing::table3());
    println!("{}", timing::table4());

    // Beyond the paper: verify the same machinery on other geometries.
    println!("== extra verification sweeps (not in the paper) ==");
    for (f, k, p, s) in [(7, 3, 1, 1), (8, 5, 2, 1), (6, 2, 0, 2), (9, 3, 0, 3)] {
        let trace = trace_kpu(KpuTraceCfg {
            f,
            k,
            p,
            s,
            cycles: f * f + 2 * (p * f + p),
        });
        match verify_kpu_trace(&trace) {
            Ok(n) => println!("f={f} k={k} p={p} s={s}: {n} labelled cells verified OK"),
            Err(e) => {
                eprintln!("f={f} k={k} p={p} s={s}: FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
}
