//! Reproduce Table X and Fig. 13: the JSC 16-16-5 MLP swept across input
//! data rates r0 = 16 .. 1/16, DSP and no-DSP variants, with the Pareto
//! frontier against the published LUT-based implementations.
//!
//! Uses the trained JSC artifact when present (measured trivial-weight
//! DSP analysis + simulated cycles); otherwise falls back to the analytic
//! models.
//!
//! ```bash
//! cargo run --release --offline --example pareto_sweep
//! ```

use cnn_flow::report::synthesis::{fig13, load_jsc_artifact, table10};

fn main() {
    let qm = load_jsc_artifact();
    match &qm {
        Some(q) => println!(
            "using trained JSC artifact (QAT accuracy {:.2}%)\n",
            q.qat_accuracy * 100.0
        ),
        None => println!("artifacts not built; using analytic models\n"),
    }
    println!("{}", table10(qm.as_ref()));
    let fig = fig13(qm.as_ref());
    println!("{fig}");
    println!("CSV (for plotting):\n{}", fig.to_csv());
}
